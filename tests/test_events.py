"""Event-subsystem contracts: AER round-trip, per-example gate parity,
measured traces, and the event-camera workload.

The load-bearing claims pinned here:

  * dense -> AER -> dense is the IDENTITY whenever capacity suffices, for
    any activity pattern (ragged, empty timesteps, bursts) — property-
    tested with hypothesis, with deterministic companions that always run
    (the scheduler-test pattern);
  * overflow is explicit: ``policy="error"`` refuses lossy conversion,
    ``policy="drop"`` keeps exactly the earliest ``capacity`` events;
  * the per-example event gate and the AER input/output paths are
    BIT-identical to the dense reference across backends x reset modes,
    on the batch scan, the masked chunk step, and the streaming feed —
    sparsity is an optimization, never an approximation;
  * the trace recorder's measured counts agree with hand counts and with
    the analytic cost-model pass (measured == analytic is the
    cross-check that makes the energy rows trustworthy).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import cerebra_h
from repro.core.engine import GATES, DecaySpec, SpikeEngine
from repro.data import events as ev_data
from repro.events.aer import AERStream, aer_to_dense, dense_to_aer
from repro.events.trace import block_traffic, measured_counts, trace_run
from repro.serving.snn import SpikeServer

from conftest import make_random_net

THRESH = 1 << 16


def _raster(rng, T, B, S, density=0.2):
    return (rng.random((T, B, S)) < density).astype(np.int32)


def _engine(W, n_in, *, backend="reference", gate="batch-tile",
            reset="zero", decay=None):
    return SpikeEngine(W, n_in, decay=decay or DecaySpec.shift(0.25),
                       threshold_raw=THRESH, reset_mode=reset,
                       backend=backend, gate=gate)


def _random_weights(rng, n_in, n_phys, density=0.3, wmax=1 << 14):
    S = n_in + n_phys
    W = (rng.random((S, n_phys)) < density) * rng.integers(
        -wmax, wmax, (S, n_phys))
    return jnp.asarray(W, jnp.int32)


# --------------------------------------------------------------------------
# AER round-trip: property test + deterministic companions
# --------------------------------------------------------------------------

@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(T=st.integers(1, 5), B=st.integers(1, 4), S=st.integers(1, 40),
       density=st.floats(0.0, 0.7), pad=st.integers(0, 9),
       seed=st.integers(0, 2**16))
@pytest.mark.slow
def test_aer_round_trip_property(T, B, S, density, pad, seed):
    """dense -> AER -> dense is the identity for ANY activity pattern
    when capacity >= event count (exact or with headroom)."""
    rng = np.random.default_rng(seed)
    dense = _raster(rng, T, B, S, density)
    stream = dense_to_aer(dense, int(dense.sum()) + pad)
    assert not stream.overflowed
    assert int(stream.count) == int(stream.total) == int(dense.sum())
    np.testing.assert_array_equal(np.asarray(aer_to_dense(stream)), dense)
    # addresses are (t, slot, source) lexicographic — the event order
    addrs = np.asarray(stream.addrs)[: int(stream.count)]
    np.testing.assert_array_equal(addrs, addrs[np.lexsort(addrs.T[::-1])])


def test_aer_round_trip_deterministic(rng):
    """The same identity on fixed corner cases (always runs)."""
    cases = [
        np.zeros((3, 2, 5), np.int32),                   # silence
        np.ones((2, 2, 4), np.int32),                    # saturation
        np.zeros((4, 1, 7), np.int32),                   # one event
        _raster(rng, 5, 3, 37, 0.15),                    # ragged activity
    ]
    cases[2][2, 0, 6] = 1
    empty_mid = _raster(rng, 6, 2, 9, 0.4)
    empty_mid[2:4] = 0                                    # empty timesteps
    cases.append(empty_mid)
    for dense in cases:
        stream = dense_to_aer(dense, int(dense.sum()) + 3)
        np.testing.assert_array_equal(
            np.asarray(aer_to_dense(stream)), dense)
        assert not stream.overflowed
        assert len(stream) == int(dense.sum())


def test_aer_overflow_policies():
    dense = np.zeros((3, 1, 4), np.int32)
    dense[0, 0, 1] = dense[1, 0, 0] = dense[2, 0, 3] = 1
    with pytest.raises(OverflowError, match="capacity"):
        dense_to_aer(dense, 2)
    # drop keeps the EARLIEST capacity events (full-FIFO semantics)
    stream = dense_to_aer(dense, 2, policy="drop")
    assert stream.overflowed
    assert (int(stream.count), int(stream.total)) == (2, 3)
    expected = dense.copy()
    expected[2, 0, 3] = 0  # the latest event is the one lost
    np.testing.assert_array_equal(np.asarray(aer_to_dense(stream)), expected)


def test_aer_validation_and_binarization():
    with pytest.raises(ValueError, match="policy"):
        dense_to_aer(np.zeros((1, 1, 1), np.int32), 1, policy="wrap")
    with pytest.raises(ValueError, match=r"\(T, B, S\)"):
        dense_to_aer(np.zeros((2, 3), np.int32), 4)
    with pytest.raises(ValueError, match="capacity"):
        dense_to_aer(np.zeros((1, 1, 1), np.int32), -1)
    # multi-valued rasters binarize: any nonzero is ONE event
    dense = np.zeros((2, 1, 3), np.int32)
    dense[1, 0, 2] = 7
    stream = dense_to_aer(dense, 4)
    assert len(stream) == 1
    np.testing.assert_array_equal(
        np.asarray(aer_to_dense(stream)), (dense != 0).astype(np.int32))


def test_aer_stream_is_a_pytree():
    """AERStream crosses jit boundaries as a static-shape pytree."""
    import jax

    dense = np.zeros((2, 1, 3), np.int32)
    dense[0, 0, 1] = 1
    stream = dense_to_aer(dense, 4)
    leaves = jax.tree_util.tree_leaves(stream)
    assert len(leaves) == 3  # addrs, count, total; shape is static meta
    out = jax.jit(lambda s: s.count + 0)(stream)
    assert int(out) == 1


# --------------------------------------------------------------------------
# Per-example gate + AER engine paths: bit-parity with the dense reference
# --------------------------------------------------------------------------

@pytest.mark.parametrize("reset", ["zero", "subtract", "hold"])
def test_per_example_gate_parity_run(rng, reset):
    """Gated pallas batch scan == dense reference, all reset modes, on a
    ragged (non-block-multiple) shape."""
    B, n_in, n_phys, T = 5, 37, 48, 6
    W = _random_weights(rng, n_in, n_phys)
    ext = _raster(rng, T, B, n_in, 0.1)
    ref = _engine(W, n_in, reset=reset).run(ext)
    gated = _engine(W, n_in, backend="pallas", gate="per-example",
                    reset=reset).run(ext)
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(gated["spikes"]))
    np.testing.assert_array_equal(np.asarray(ref["v_final"]),
                                  np.asarray(gated["v_final"]))


def test_aer_input_output_parity(rng):
    """AER in == dense in; AER out decodes to the exact output raster."""
    B, n_in, n_phys, T = 3, 29, 40, 5
    W = _random_weights(rng, n_in, n_phys)
    ext = _raster(rng, T, B, n_in, 0.15)
    stream = dense_to_aer(ext, int(ext.sum()))
    for backend, gate in [("reference", "batch-tile"),
                          ("pallas", "per-example")]:
        eng = _engine(W, n_in, backend=backend, gate=gate)
        dense_out = eng.run(ext)
        aer_out = eng.run(stream, events_capacity=int(
            np.asarray(dense_out["spikes"]).sum()) + 2)
        np.testing.assert_array_equal(np.asarray(dense_out["spikes"]),
                                      np.asarray(aer_out["spikes"]))
        np.testing.assert_array_equal(
            np.asarray(aer_to_dense(aer_out["events"])),
            np.asarray(dense_out["spikes"]))


def test_engine_aer_validation(rng):
    W = _random_weights(rng, 8, 8)
    eng = _engine(W, 8)
    bad = dense_to_aer(np.zeros((2, 1, 5), np.int32), 1)
    with pytest.raises(ValueError, match="sources"):
        eng.run(bad)
    # above-threshold weights: every neuron spikes, so capacity 0 is lossy
    W_hot = jnp.full((8 + 8, 8), 1 << 17, jnp.int32)
    hot = _engine(W_hot, 8)
    ext = np.ones((2, 1, 8), np.int32)
    with pytest.raises(OverflowError):
        hot.run(ext, events_capacity=0)  # default policy refuses loss
    out = hot.run(ext, events_capacity=0, events_policy="drop")
    assert out["events"].overflowed and int(out["events"].count) == 0


def test_gate_validation_and_rehost(rng):
    W = _random_weights(rng, 6, 10)
    with pytest.raises(ValueError, match="gate"):
        _engine(W, 6, gate="per-cluster")
    eng = _engine(W, 6, backend="pallas")
    assert eng.with_gate("batch-tile") is eng
    gated = eng.with_gate("per-example")
    assert gated.gate == "per-example" and gated.backend == "pallas"
    assert gated.weights_raw is eng.weights_raw


def test_mesh_engine_keeps_gate(rng):
    """with_gate on a mesh engine must stay a mesh engine (degenerate
    1x1 mesh keeps this covered on a single device)."""
    from repro.distributed.spike_mesh import MeshSpikeEngine, make_spike_mesh

    W = _random_weights(rng, 12, 16)
    mesh = make_spike_mesh(neuron=1, batch=1)
    eng = _engine(W, 12).to_mesh(mesh).with_gate("per-example")
    assert isinstance(eng, MeshSpikeEngine)
    assert eng.gate == "per-example" and eng.mesh is mesh
    ext = _raster(np.random.default_rng(3), 4, 2, 12, 0.2)
    ref = _engine(W, 12).run(ext)
    np.testing.assert_array_equal(np.asarray(eng.run(ext)["spikes"]),
                                  np.asarray(ref["spikes"]))


def test_per_example_gate_parity_step_chunk(rng):
    """Masked chunk step under the per-example gate: active slots advance
    exactly, inactive slots keep their carry bit-for-bit."""
    B, n_in, n_phys, T = 4, 21, 24, 6
    W = _random_weights(rng, n_in, n_phys)
    ext = _raster(rng, T, B, n_in, 0.25)
    active = (rng.random((T, B)) < 0.6).astype(np.int32)
    ref_e = _engine(W, n_in, reset="subtract")
    gat_e = _engine(W, n_in, backend="pallas", gate="per-example",
                    reset="subtract")
    c_ref = ref_e.init_carry(B)
    c_gat = gat_e.init_carry(B)
    c_ref, s_ref = ref_e.step_chunk(c_ref, ext, active)
    c_gat, s_gat = gat_e.step_chunk(c_gat, ext, active)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_gat))
    for k in ("v", "spikes"):
        np.testing.assert_array_equal(np.asarray(c_ref[k]),
                                      np.asarray(c_gat[k]))


def test_streaming_feed_parity_per_example_gate(rng):
    """Chunked SpikeServer.feed on a per-example-gated engine is
    byte-identical to the one-shot dense-reference scan, with a
    co-resident stream churning in another slot."""
    n_in, n_phys, T = 13, 16, 9
    W = _random_weights(rng, n_in, n_phys, density=0.5)
    ref_e = _engine(W, n_in, reset="hold")
    srv = SpikeServer(_engine(W, n_in, backend="pallas", reset="hold"),
                      n_slots=3, chunk_steps=4, gate="per-example")
    assert srv.engine.gate == "per-example"
    a, b = srv.attach(), srv.attach()
    ra = _raster(rng, T, 1, n_in, 0.3)[:, 0]
    rb = _raster(rng, T + 2, 1, n_in, 0.2)[:, 0]  # ragged lengths
    out = srv.feed({a: ra, b: rb})
    for uid, raster in [(a, ra), (b, rb)]:
        solo = ref_e.run(raster[:, None, :])["spikes"][:, 0]
        np.testing.assert_array_equal(out[uid]["spikes"], np.asarray(solo))


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["pallas", "pallas-mxu"])
@pytest.mark.parametrize("reset", ["zero", "subtract", "hold"])
@pytest.mark.parametrize("decay_kind", ["shift", "mul"])
def test_event_paths_full_sweep(rng, backend, reset, decay_kind):
    """The acceptance sweep: per-example gate + AER input + streaming
    feed, bit-identical to the dense reference, across backends x reset
    modes x decay units."""
    decay = (DecaySpec.shift(0.25) if decay_kind == "shift"
             else DecaySpec.mul(int(0.8 * 65536)))
    B, n_in, n_phys, T = 5, 37, 48, 7
    W = _random_weights(rng, n_in, n_phys, wmax=1 << 13)
    ext = _raster(rng, T, B, n_in, 0.12)
    ref = _engine(W, n_in, reset=reset, decay=decay).run(ext)
    eng = _engine(W, n_in, backend=backend, gate="per-example",
                  reset=reset, decay=decay)
    # batch run, fed by AER, emitting AER
    out = eng.run(dense_to_aer(ext, int(ext.sum())),
                  events_capacity=int(np.asarray(ref["spikes"]).sum()))
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(out["spikes"]))
    np.testing.assert_array_equal(np.asarray(ref["v_final"]),
                                  np.asarray(out["v_final"]))
    np.testing.assert_array_equal(
        np.asarray(aer_to_dense(out["events"])), np.asarray(ref["spikes"]))
    # streaming feed_events on the same program
    srv = SpikeServer(eng, n_slots=2, chunk_steps=3)
    uid = srv.attach()
    res = srv.feed_events(
        {uid: dense_to_aer(ext[:, :1], max(int(ext[:, :1].sum()), 1))},
        out_capacity=int(np.asarray(ref["spikes"][:, 0]).sum()) + 1)
    np.testing.assert_array_equal(res[uid]["spikes"],
                                  np.asarray(ref["spikes"][:, 0]))
    np.testing.assert_array_equal(
        np.asarray(aer_to_dense(res[uid]["events"]))[:, 0],
        np.asarray(ref["spikes"][:, 0]))


# --------------------------------------------------------------------------
# Serving event paths (deterministic, always run)
# --------------------------------------------------------------------------

def test_feed_events_matches_feed(rng):
    n_in, n_phys, T = 11, 12, 6
    W = _random_weights(rng, n_in, n_phys, density=0.5)
    srv_a = SpikeServer(_engine(W, n_in), n_slots=2, chunk_steps=4)
    srv_b = SpikeServer(_engine(W, n_in), n_slots=2, chunk_steps=4)
    u_a, u_b = srv_a.attach(), srv_b.attach()
    chunk = _raster(rng, T, 1, n_in, 0.3)
    dense_out = srv_a.feed({u_a: chunk[:, 0]})
    ev_out = srv_b.feed_events(
        {u_b: dense_to_aer(chunk, int(chunk.sum()))},
        out_capacity=64)
    np.testing.assert_array_equal(dense_out[u_a]["spikes"],
                                  ev_out[u_b]["spikes"])
    np.testing.assert_array_equal(
        np.asarray(aer_to_dense(ev_out[u_b]["events"]))[:, 0],
        ev_out[u_b]["spikes"])


def test_feed_events_validation(rng):
    W = _random_weights(rng, 6, 8)
    srv = SpikeServer(_engine(W, 6), n_slots=1, chunk_steps=2)
    uid = srv.attach()
    wide = dense_to_aer(np.zeros((2, 2, 6), np.int32), 1)
    with pytest.raises(ValueError, match="AER chunk"):
        srv.feed_events({uid: wide})
    wrong = dense_to_aer(np.zeros((2, 1, 5), np.int32), 1)
    with pytest.raises(ValueError, match="AER chunk"):
        srv.feed_events({uid: wrong})


def test_session_serve_gate_in_server_key(rng):
    """A group served under one gate cannot be silently re-served under
    another (separate carries would fork the stream state)."""
    from repro.core.session import AcceleratorSession

    sess = AcceleratorSession()
    sess.deploy("m", make_random_net(rng, n_in=6, n_neurons=12))
    view = sess.serve("m", n_slots=2, gate="per-example")
    assert view.server.engine.gate == "per-example"
    with pytest.raises(ValueError, match="already served"):
        sess.serve("m", n_slots=2)
    # gate=None and the explicit default alias to the SAME server key
    sess2 = AcceleratorSession()
    sess2.deploy("m", make_random_net(rng, n_in=6, n_neurons=12))
    v_default = sess2.serve("m", n_slots=2)
    v_explicit = sess2.serve("m", n_slots=2, gate="batch-tile")
    assert v_explicit.server is v_default.server


# --------------------------------------------------------------------------
# Trace recorder: measured counts
# --------------------------------------------------------------------------

def test_block_traffic_hand_checked():
    # T=2, B=3, S=4; block_src=2 -> 2 source blocks; tile_batch=2 -> 2
    # batch tiles (second tile is one padded row).
    src = np.zeros((2, 3, 4), np.int32)
    src[0, 0, 0] = 1          # t0: tile0 touches block0
    src[0, 2, 3] = 1          # t0: tile1 touches block1
    src[1, 1, 1] = 1          # t1: tile0 touches block0
    touched, total = block_traffic(src, block_src=2, tile_batch=2)
    assert (touched, total) == (3, 2 * 2 * 2)
    per_ex, per_total = block_traffic(src, block_src=2, tile_batch=1)
    assert (per_ex, per_total) == (3, 2 * 3 * 2)
    assert block_traffic(np.zeros((2, 3, 4), np.int32),
                         block_src=2, tile_batch=1) == (0, 12)


def test_trace_run_measured_sops_hand_checked():
    # 2 inputs, 2 neurons; input0 fans out to both neurons, input1 to
    # none, neuron0 feeds neuron1. Thresholds high: no output spikes.
    W = jnp.asarray([[1 << 10, 1 << 10],      # input 0: fanout 2
                     [0, 0],                   # input 1: fanout 0
                     [0, 1 << 10],             # neuron 0: fanout 1
                     [0, 0]], jnp.int32)       # neuron 1: fanout 0
    eng = _engine(W, 2)
    ext = np.zeros((3, 1, 2), np.int32)
    ext[0, 0, 0] = 1   # 2 SOPs
    ext[1, 0, 1] = 1   # 0 SOPs
    ext[2, 0, 0] = 1   # 2 SOPs
    out = eng.run(ext)
    rep = trace_run(eng, ext, out["spikes"])
    assert rep.measured_sops == 4
    assert rep.source_events == 3
    assert rep.output_events == int(np.asarray(out["spikes"]).sum())
    assert rep.dense_sops == 3 * 1 * 3  # T*B*sum(fanout)
    assert 0.0 < rep.source_sparsity < 1.0
    assert "SOPs" in rep.summary()


def test_trace_accepts_aer_streams(rng):
    W = _random_weights(rng, 9, 12)
    eng = _engine(W, 9)
    ext = _raster(rng, 4, 2, 9, 0.3)
    out = eng.run(ext)["spikes"]
    dense_rep = trace_run(eng, ext, out)
    aer_rep = trace_run(eng, dense_to_aer(ext, int(ext.sum())),
                        dense_to_aer(out, int(np.asarray(out).sum())))
    assert dense_rep == aer_rep


def test_measured_counts_agree_with_cost_model(rng):
    """Measured event accounting == the analytic cost-model pass, on the
    same rasters (the cross-check behind table_v --measured-sop)."""
    from repro.core.mapping import ClusterGeometry

    geom = ClusterGeometry(n_clusters=4, neurons_per_cluster=4,
                           clusters_per_group=2, rows_per_group=64,
                           clusters_per_l1=2)
    net = make_random_net(rng, n_in=5, n_neurons=12, density=0.5)
    prog = cerebra_h.compile_network(net, cerebra_h.CerebraHConfig(
        geometry=geom))
    ext = _raster(rng, 8, 2, 5, 0.4)
    out = cerebra_h.run(prog, ext)
    counts = measured_counts(prog, ext, out["spikes"])
    assert counts.sops == float(np.sum(np.asarray(out["sops"])))
    assert counts.row_fetches == float(
        np.sum(np.asarray(out["row_fetches"])))
    assert counts.cycles == float(np.sum(np.asarray(out["cycles"])))


# --------------------------------------------------------------------------
# Event-camera workload
# --------------------------------------------------------------------------

def test_gesture_raster_contract():
    d1, l1 = ev_data.gesture_raster("test", 5, steps=16, size=12, seed=3)
    d2, l2 = ev_data.gesture_raster("test", 5, steps=16, size=12, seed=3)
    np.testing.assert_array_equal(d1, d2)       # deterministic
    np.testing.assert_array_equal(l1, l2)
    assert d1.shape == (16, 5, ev_data.n_channels(12))
    assert set(np.unique(d1)) <= {0, 1}
    assert l1.min() >= 0 and l1.max() < len(ev_data.GESTURES)
    assert 0.0 < d1.mean() < 0.15               # event-sparse
    d3, _ = ev_data.gesture_raster("train", 5, steps=16, size=12, seed=3)
    assert not np.array_equal(d1, d3)           # splits differ


def test_gesture_events_round_trip():
    stream, labels = ev_data.gesture_events("test", 3, steps=12, size=10,
                                            seed=1)
    assert isinstance(stream, AERStream)
    assert not stream.overflowed                # auto-sized capacity
    dense, labels2 = ev_data.gesture_raster("test", 3, steps=12, size=10,
                                            seed=1)
    np.testing.assert_array_equal(np.asarray(aer_to_dense(stream)), dense)
    np.testing.assert_array_equal(labels, labels2)


def test_gesture_classes_distinct():
    """Different trajectories produce different event streams (the labels
    carry signal even though the demo net is untrained)."""
    rng = np.random.default_rng(0)
    del rng
    d, labels = ev_data.gesture_raster("test", 16, steps=16, size=12,
                                       seed=7)
    by_class: dict = {}
    for i, lab in enumerate(labels):
        by_class.setdefault(int(lab), d[:, i])
    classes = list(by_class)
    assert len(classes) >= 2
    a, b = by_class[classes[0]], by_class[classes[1]]
    assert not np.array_equal(a, b)
