"""§Perf variant correctness: every optimization must be bit-compatible
(or numerically indistinguishable) with the paper-faithful baseline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.common import rms_norm


def test_split_cache_decode_matches_forward(rng):
    """Cell C: gemma3 ring caches for local layers — decode far past the
    window must reproduce teacher-forced logits."""
    mod = configs.get_arch("gemma3-12b")
    cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32,
                              split_cache=True)
    model = mod.build(cfg)
    params = model.init(jax.random.key(1))
    B, S, k = 2, 30, 6  # 30 >> window 8
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S)
    # local caches must be ring-sized, globals full
    assert cache["local"]["k"].shape[3] == cfg.sliding_window
    assert cache["global"]["k"].shape[2] == S
    logits, cache = model.prefill(params, {"tokens": toks[:, :k]}, cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, k - 1]),
                               rtol=2e-3, atol=2e-3)
    for pos in range(k, S):
        logits, cache = model.decode_step(
            params, {"tokens": toks[:, pos:pos + 1]}, jnp.int32(pos), cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, pos]),
            rtol=2e-3, atol=2e-3, err_msg=f"pos {pos}")


def test_vocab_padding_preserves_loss_and_decode(rng):
    """Cell B: Megatron-style vocab padding — losses match the unpadded
    model up to init noise in used columns; pad columns never win argmax."""
    mod = configs.get_arch("granite-3-2b")
    base = dataclasses.replace(mod.REDUCED, dtype=jnp.float32,
                               vocab_size=250)
    padded = dataclasses.replace(base, vocab_pad_to=64)  # 250 -> 256
    m_pad = mod.build(padded)
    params = m_pad.init(jax.random.key(2))
    assert params["embed"]["table"].shape[0] == 256
    toks = jnp.asarray(rng.integers(1, 250, (2, 16)), jnp.int32)
    logits, _ = m_pad.forward(params, {"tokens": toks})
    assert logits.shape[-1] == 256
    # pad columns are -inf-masked: never selected, softmax mass zero
    assert int(jnp.argmax(logits, -1).max()) < 250
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    assert float(probs[..., 250:].sum()) < 1e-6
    loss, _ = m_pad.loss(params, {"tokens": toks, "targets": toks})
    assert np.isfinite(float(loss))


def test_rms_norm_custom_vjp_matches_autodiff(rng):
    """Cell B: the bf16-boundary norm VJP is exact vs plain autodiff."""
    def plain(x, scale, eps=1e-6):
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
        return y.astype(x.dtype)

    x = jnp.asarray(rng.normal(0, 1, (4, 8, 32)), jnp.float32)
    s = jnp.asarray(rng.normal(0, 0.1, (32,)), jnp.float32)
    ga = jax.grad(lambda x, s: jnp.sum(jnp.sin(rms_norm(x, s))),
                  argnums=(0, 1))(x, s)
    gb = jax.grad(lambda x, s: jnp.sum(jnp.sin(plain(x, s))),
                  argnums=(0, 1))(x, s)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    # and the boundary cotangent dtype follows the input dtype
    xb = x.astype(jnp.bfloat16)
    g = jax.grad(lambda x: jnp.sum(rms_norm(x, s).astype(jnp.float32)))(xb)
    assert g.dtype == jnp.bfloat16


def test_attn_scores_bf16_close_to_f32(rng):
    mod = configs.get_arch("granite-3-2b")
    cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32)
    cfg_b = dataclasses.replace(cfg, attn_scores_bf16=True)
    m_a, m_b = mod.build(cfg), mod.build(cfg_b)
    params = m_a.init(jax.random.key(3))
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 16)), jnp.int32)
    la, _ = m_a.forward(params, {"tokens": toks})
    lb, _ = m_b.forward(params, {"tokens": toks})
    # bf16 score quantization shifts logits slightly but not rankings
    top_a = np.asarray(jnp.argmax(la, -1))
    top_b = np.asarray(jnp.argmax(lb, -1))
    assert (top_a == top_b).mean() > 0.9


def test_moe_ep_matches_dense_dispatch(tmp_path, rng):
    """Cell A forward path: shard_map expert parallelism must reproduce
    the dense-dispatch outputs (dropless). Runs on 8 fake host devices in
    a subprocess so the 512-device flag never leaks into this process."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import moe as moe_mod
mesh = jax.make_mesh((4, 2), ("data", "model"))
mod = configs.get_arch('mixtral-8x7b')
cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32)
rng = np.random.default_rng(0)
p = jax.tree.map(lambda x: x.astype(jnp.float32),
                 moe_mod.init_moe(jax.random.key(0), cfg))
x = jnp.asarray(rng.normal(0, 0.5, (4, 16, cfg.d_model)), jnp.float32)
with mesh:
    d_out, _ = jax.jit(
        lambda p, x: moe_mod.moe_forward(p, x, cfg, dropless=True))(p, x)
    xs = jax.device_put(x, NamedSharding(mesh, P("data")))
    e_out, _ = jax.jit(
        lambda p, x: moe_mod.moe_forward_ep(p, x, cfg, dropless=True))(p, xs)
np.testing.assert_allclose(np.asarray(e_out), np.asarray(d_out),
                           rtol=2e-4, atol=2e-5)
print("OK")
"""
    # deterministic subprocess environment: drop any inherited JAX/XLA
    # configuration (an ambient XLA_FLAGS or JAX_PLATFORMS would fight the
    # 8-fake-device setup — the historical flake), then pin CPU + devices.
    env = {k: v for k, v in os.environ.items()
           if not k.startswith(("JAX_", "XLA_"))}
    env.update(PYTHONPATH=os.path.join(repo, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    for attempt in range(2):
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=600)
        if proc.returncode == 0:
            break
        if proc.returncode > 0:
            break  # a real Python failure: do not mask it by retrying
        # negative returncode = killed by a signal (OOM/SIGKILL under CI
        # memory pressure): transient, retry once
    assert proc.returncode == 0 and "OK" in proc.stdout, (
        f"rc={proc.returncode}\n{proc.stderr[-2000:]}")


def test_moe_ep_falls_back_without_mesh(rng):
    """On a plain CPU device (no mesh) the EP path must transparently use
    the dense dispatch."""
    mod = configs.get_arch("mixtral-8x7b")
    cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32, moe_ep=True)
    model = mod.build(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 8)), jnp.int32)
    loss, _ = model.loss(params, {"tokens": toks, "targets": toks})
    assert np.isfinite(float(loss))


def test_remat_policies_same_loss(rng):
    mod = configs.get_arch("granite-3-2b")
    toks = jnp.asarray(rng.integers(1, 200, (2, 16)), jnp.int32)
    losses = []
    for pol in ("nothing", "attn_out", "dots"):
        cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32,
                                  remat_policy=pol)
        model = mod.build(cfg)
        params = model.init(jax.random.key(4))
        loss, _ = model.loss(params, {"tokens": toks, "targets": toks},
                             remat=True)
        g = jax.grad(lambda p: model.loss(
            p, {"tokens": toks, "targets": toks}, remat=True)[0])(params)
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
        losses.append(float(loss))
    np.testing.assert_allclose(losses, losses[0], rtol=1e-5)
