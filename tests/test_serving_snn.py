"""Streaming serving parity + lifecycle contracts.

The acceptance criterion of PR 2: for every backend and reset mode,
chunked ``SpikeServer.feed`` over ragged timestep boundaries is
BYTE-for-byte identical to one-shot ``SpikeEngine.run`` on the same
raster — streaming must be a pure re-chunking of the batch semantics,
never a different numerical path. Plus the stream-lifecycle contract:
attach/evict/re-attach churn in some slots leaves co-resident slots'
state bit-for-bit untouched.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding
from repro.core.engine import BACKENDS, DecaySpec, SpikeEngine
from repro.core.lif import LIFParams
from repro.core.network import SNNetwork
from repro.core.session import AcceleratorSession
from repro.serving.snn import SpikeServer

THRESH = 1 << 16
RESET_MODES = ("zero", "subtract", "hold")


def _engine(rng, *, backend="reference", n_in=10, n_phys=16,
            reset="subtract", decay=None, wmax=1 << 13):
    S = n_in + n_phys
    W = (rng.random((S, n_phys)) < 0.4) * rng.integers(-wmax, wmax, (S, n_phys))
    return SpikeEngine(jnp.asarray(W, jnp.int32), n_in,
                       decay=decay or DecaySpec.shift(0.25),
                       threshold_raw=THRESH, reset_mode=reset,
                       backend=backend)


def _raster(rng, T, n_in, p=0.35):
    return (rng.random((T, 1, n_in)) < p).astype(np.int32)


def _feed_ragged(server, uid, raster, sizes):
    """Feed raster (T, n_in) in ragged pieces; return concatenated spikes."""
    assert sum(sizes) == raster.shape[0]
    out, t0 = [], 0
    for n in sizes:
        out.append(server.feed({uid: raster[t0:t0 + n]})[uid]["spikes"])
        t0 += n
    return np.concatenate(out, axis=0)


def _assert_stream_equals_batch(engine, rng, *, sizes=(2, 3, 1, 3),
                                chunk_steps=3, n_slots=3):
    T = sum(sizes)
    raster = _raster(rng, T, engine.n_inputs)
    want = np.asarray(engine.run(raster)["spikes"])[:, 0]
    server = SpikeServer(engine, n_slots=n_slots, chunk_steps=chunk_steps)
    uid = server.attach()
    got = _feed_ragged(server, uid, raster[:, 0], sizes)
    assert got.dtype == want.dtype == np.int32  # byte-for-byte, not just ==
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# Parity: fast leg (reference backend; every reset mode; ragged chunking)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("reset", RESET_MODES)
def test_feed_chunked_parity_reference(rng, reset):
    engine = _engine(rng, reset=reset)
    _assert_stream_equals_batch(engine, rng)


@pytest.mark.parametrize("sizes", [(9,), (1,) * 9, (4, 5), (1, 6, 2)])
def test_feed_ragged_boundaries(rng, sizes):
    """Chunk boundaries anywhere — including chunk > chunk_steps (internal
    re-chunking) and T=1 dribble — never change a bit."""
    engine = _engine(rng)
    _assert_stream_equals_batch(engine, rng, sizes=sizes)


def test_feed_mul_decay_parity(rng):
    """The Cerebra-S truncating-multiply PDU streams exactly too."""
    engine = _engine(rng, decay=DecaySpec.mul(int(round(0.7 * 65536))))
    _assert_stream_equals_batch(engine, rng)


# --------------------------------------------------------------------------
# Parity: the full sweep — every backend x every reset mode (CI slow leg;
# the driver's tier-1 run executes it unconditionally)
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("reset", RESET_MODES)
def test_feed_parity_sweep(rng, backend, reset):
    engine = _engine(rng, backend=backend, reset=reset)
    _assert_stream_equals_batch(engine, rng)


# --------------------------------------------------------------------------
# Lifecycle: churn isolation, eviction zeroing, admission queue
# --------------------------------------------------------------------------

def test_interleaved_streams_match_solo(rng):
    """Two streams fed interleaved, ragged, and staggered: each equals its
    solo batch run (slots are independent lanes)."""
    engine = _engine(rng)
    ra, rb = _raster(rng, 11, 10), _raster(rng, 11, 10, p=0.5)
    server = SpikeServer(engine, n_slots=4, chunk_steps=3)
    a, b = server.attach(), server.attach()
    ga, gb = [], []
    o = server.feed({a: ra[0:4, 0]})
    ga.append(o[a]["spikes"])
    o = server.feed({a: ra[4:5, 0], b: rb[0:7, 0]})
    ga.append(o[a]["spikes"]); gb.append(o[b]["spikes"])
    o = server.feed({b: rb[7:11, 0], a: ra[5:11, 0]})
    ga.append(o[a]["spikes"]); gb.append(o[b]["spikes"])
    np.testing.assert_array_equal(np.concatenate(ga, 0),
                                  np.asarray(engine.run(ra)["spikes"])[:, 0])
    np.testing.assert_array_equal(np.concatenate(gb, 0),
                                  np.asarray(engine.run(rb)["spikes"])[:, 0])


def test_churn_leaves_coresident_slots_untouched(rng):
    """attach/evict/re-attach churn around a long-lived stream must not
    perturb it by a single bit."""
    engine = _engine(rng)
    T = 12
    keeper_r = _raster(rng, T, 10)
    want = np.asarray(engine.run(keeper_r)["spikes"])[:, 0]
    server = SpikeServer(engine, n_slots=3, chunk_steps=4)
    keeper = server.attach()
    got = []
    for t in range(T):
        # churn: a transient stream attaches, feeds noise, and is evicted
        # every step while the keeper streams on
        trans = server.attach()
        noise = (rng.random((2, 10)) < 0.6).astype(np.int32)
        server.feed({trans: noise})
        got.append(server.feed({keeper: keeper_r[t:t + 1, 0]})[keeper]["spikes"])
        server.detach(trans)
    np.testing.assert_array_equal(np.concatenate(got, 0), want)


def test_eviction_zeroes_carry_and_reattach_is_fresh(rng):
    """Detach zeroes the slot; the next occupant of the SAME slot powers
    up from the unified initial state (bit-identical to a fresh server)."""
    engine = _engine(rng)
    raster = _raster(rng, 9, 10)
    want = np.asarray(engine.run(raster)["spikes"])[:, 0]
    server = SpikeServer(engine, n_slots=1, chunk_steps=4)
    a = server.attach()
    server.feed({a: (rng.random((7, 10)) < 0.5).astype(np.int32)})
    server.detach(a)
    np.testing.assert_array_equal(np.asarray(server.carry["v"]), 0)
    np.testing.assert_array_equal(np.asarray(server.carry["spikes"]), 0)
    b = server.attach()
    assert server.slot_of(b) == 0  # same physical slot, recycled
    got = _feed_ragged(server, b, raster[:, 0], (4, 5))
    np.testing.assert_array_equal(got, want)


def test_admission_queue_fifo_and_feed_guard(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    a = server.attach()
    b = server.attach()
    c = server.attach()
    assert server.slot_of(a) == 0
    assert server.slot_of(b) is None and server.slot_of(c) is None
    with pytest.raises(ValueError, match="waiting"):
        server.feed({b: np.zeros((1, 10), np.int32)})
    server.detach(a)
    assert server.slot_of(b) == 0      # FIFO: b before c
    assert server.slot_of(c) is None
    server.detach(b)
    assert server.slot_of(c) == 0


def test_zero_length_chunk_is_per_stream_noop(rng):
    """T=0 chunks (an idle stream this round) return an empty raster and
    leave the carry untouched — mixed calls still serve the live streams."""
    engine = _engine(rng)
    raster = _raster(rng, 8, 10)
    want = np.asarray(engine.run(raster)["spikes"])[:, 0]
    server = SpikeServer(engine, n_slots=2, chunk_steps=4)
    a, b = server.attach(), server.attach()
    empty = np.zeros((0, 10), np.int32)
    o = server.feed({a: empty})
    assert o[a]["spikes"].shape == (0, 16)
    got = []
    for t0, t1 in ((0, 3), (3, 8)):
        o = server.feed({a: raster[t0:t1, 0], b: empty})
        got.append(o[a]["spikes"])
        assert o[b]["spikes"].shape == (0, 16)
    np.testing.assert_array_equal(np.concatenate(got, 0), want)
    assert server.streams[b].steps == 0


def test_auto_uid_skips_caller_chosen_ids(rng):
    """Explicit and auto-generated uids coexist on one server."""
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=4, chunk_steps=2)
    server.attach(0)
    server.attach(2)
    auto1 = server.attach()
    auto2 = server.attach()
    assert len({0, 2, auto1, auto2}) == 4


def test_closed_loop_replay_matches_batch(rng):
    """Closed-loop stepping with a controller that replays a fixed raster
    is the identity case: byte-identical to the batch scan."""
    engine = _engine(rng)
    raster = _raster(rng, 8, 10)
    want = np.asarray(engine.run(raster)["spikes"])[:, 0]
    server = SpikeServer(engine, n_slots=2, chunk_steps=4)
    uid = server.attach()
    step = {"t": 0}

    def controller(spikes_t):
        step["t"] += 1
        return raster[step["t"], 0]

    out = server.run_closed_loop(uid, controller, 8, raster[0, 0])
    np.testing.assert_array_equal(out["spikes"], want)


def test_closed_loop_feedback_depends_on_output(rng):
    """The loop is actually closed: a controller keyed off the spike count
    produces a different input stream than open-loop replay would."""
    engine = _engine(rng, wmax=1 << 15)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    uid = server.attach()
    seen = []

    def controller(spikes_t):
        seen.append(int(spikes_t.sum()))
        # fire the encoder only when the array was quiet at step t
        return np.full((10,), int(spikes_t.sum() == 0), np.int32)

    out = server.run_closed_loop(uid, controller, 10, np.ones(10, np.int32))
    assert out["spikes"].shape == (10, 16)
    assert len(seen) == 9  # output of step t consumed at t+1, none after T


# --------------------------------------------------------------------------
# Session entry: co-resident models stream together over the fused engine
# --------------------------------------------------------------------------

def _net(rng, n_in=6, n_neurons=12, decay_rate=0.25, reset="zero"):
    W = ((rng.random((n_in + n_neurons, n_neurons)) < 0.4)
         * rng.normal(0.0, 0.5, (n_in + n_neurons, n_neurons)))
    return SNNetwork(
        n_inputs=n_in, n_neurons=n_neurons, weights=W.astype(np.float32),
        params=LIFParams(decay_rate=decay_rate, threshold=1.0,
                         reset_mode=reset),
        output_slice=(n_neurons - 4, n_neurons))


def test_session_serve_matches_batch_run(rng):
    """session.serve streaming output == session.run (same key, same
    encoder) for a resident model — counts and predictions identical."""
    sess = AcceleratorSession()
    sess.deploy("m", _net(rng))
    import jax
    key = jax.random.key(7)
    intensities = rng.random((1, 6)).astype(np.float32)
    T = 12
    want = sess.run("m", intensities, T, key)

    stream = sess.serve("m", n_slots=2, chunk_steps=5)
    uid = stream.attach()
    ext = np.asarray(coding.poisson_encode(key, intensities, T,
                                           dtype=np.int32))[:, 0]
    got = [stream.feed(uid, ext[0:4]), stream.feed(uid, ext[4:12])]
    counts = got[0]["output_counts"] + got[1]["output_counts"]
    np.testing.assert_array_equal(counts,
                                  np.asarray(want["output_counts"])[0])
    spikes = np.concatenate([g["spikes"] for g in got], axis=0)
    np.testing.assert_array_equal(spikes, np.asarray(want["spikes"])[:, 0])


def test_coresident_models_share_one_server(rng):
    """Models with one LIF config stream through ONE fused-engine server;
    each stream's decode equals its solo deployment, concurrently."""
    netA, netB = _net(rng), _net(rng, n_in=5, n_neurons=10)
    sess = AcceleratorSession()
    sess.deploy("A", netA)
    sess.deploy("B", netB)
    sA = sess.serve("A", n_slots=3, chunk_steps=4)
    sB = sess.serve("B", n_slots=3, chunk_steps=4)
    assert sA.server is sB.server  # one compiled step for the group

    rA = (rng.random((9, 6)) < 0.4).astype(np.int32)
    rB = (rng.random((9, 5)) < 0.4).astype(np.int32)

    a, b = sA.attach(), sB.attach()
    outA = [sA.feed(a, rA[:4]), sA.feed(a, rA[4:])]
    outB = [sB.feed(b, rB[:6]), sB.feed(b, rB[6:])]

    from repro.core import cerebra_h
    for name, net, raster, outs, view in (("A", netA, rA, outA, sA),
                                          ("B", netB, rB, outB, sB)):
        solo = AcceleratorSession()
        model = solo.deploy(name, net)
        want = cerebra_h.run(model.program, raster[:, None, :])
        counts = sum(o["output_counts"] for o in outs)
        np.testing.assert_array_equal(
            counts, np.asarray(want["output_counts"])[0])
        # physical placement differs (solo deploys at cluster 0; the fused
        # layout offsets later models) but the model's own cluster-range
        # slice must be bit-identical
        lo, hi = view.phys_slice
        slo, shi = (model.cluster_range[0] * 32, model.cluster_range[1] * 32)
        spikes = np.concatenate([o["spikes"] for o in outs], axis=0)
        np.testing.assert_array_equal(
            spikes[:, lo:hi], np.asarray(want["spikes"])[:, 0, slo:shi])


def test_serve_rejects_waiting_and_unknown(rng):
    sess = AcceleratorSession()
    sess.deploy("m", _net(rng))
    stream = sess.serve("m", n_slots=1)
    with pytest.raises(KeyError):
        stream.slot_of("nope")
    with pytest.raises(KeyError):
        sess.serve("ghost")


def test_serve_rejects_mismatched_slot_params(rng):
    """One server per co-resident group: a second serve() with different
    slot parameters must raise, not silently split the carries."""
    sess = AcceleratorSession()
    sess.deploy("a", _net(rng))
    sess.deploy("b", _net(rng, n_in=5, n_neurons=10))
    sess.serve("a", n_slots=2, chunk_steps=4)
    with pytest.raises(ValueError, match="already served"):
        sess.serve("b", n_slots=4, chunk_steps=4)
    assert sess.serve("b", n_slots=2, chunk_steps=4) is not None


def test_closed_loop_rejects_malformed_controller_output(rng):
    """A controller returning the wrong shape fails loudly instead of
    broadcasting across all input lines."""
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    uid = server.attach()
    with pytest.raises(ValueError, match="controller must return"):
        server.run_closed_loop(uid, lambda s: 1, 3, np.zeros(10, np.int32))
    sess = AcceleratorSession()
    sess.deploy("m", _net(rng))
    stream = sess.serve("m")
    u2 = stream.attach()
    with pytest.raises(ValueError, match="controller must return"):
        stream.run_closed_loop(u2, lambda s: 1, 3, np.zeros(6, np.int32))


def test_stale_view_raises_after_deploy(rng):
    """deploy() changes the fused layout: an outstanding ModelStream view
    must fail loudly, not stream against the pre-deploy engine."""
    sess = AcceleratorSession()
    sess.deploy("m", _net(rng))
    stream = sess.serve("m", n_slots=2, chunk_steps=4)
    uid = stream.attach()
    stream.feed(uid, np.zeros((2, 6), np.int32))  # fresh view works
    sess.deploy("n", _net(rng, n_in=5, n_neurons=10))
    with pytest.raises(RuntimeError, match="stale"):
        stream.feed(uid, np.zeros((2, 6), np.int32))
    with pytest.raises(RuntimeError, match="stale"):
        stream.attach()
    with pytest.raises(RuntimeError, match="stale"):
        stream.run_closed_loop(uid, lambda s: np.zeros(6, np.int32), 2,
                               np.zeros(6, np.int32))
    fresh = sess.serve("m")  # re-serving after the deploy is the fix
    uid2 = fresh.attach()
    fresh.feed(uid2, np.zeros((2, 6), np.int32))


def test_feed_many_single_dispatch_matches_per_stream(rng):
    """Batched feed_many over several of a model's streams equals the
    per-stream feed results (one slot-batch dispatch, same bits)."""
    net = _net(rng)
    sess_a = AcceleratorSession()
    sess_a.deploy("m", net)
    sess_b = AcceleratorSession()
    sess_b.deploy("m", net)
    va = sess_a.serve("m", n_slots=3, chunk_steps=4)
    vb = sess_b.serve("m", n_slots=3, chunk_steps=4)
    r1 = (rng.random((7, 6)) < 0.4).astype(np.int32)
    r2 = (rng.random((7, 6)) < 0.5).astype(np.int32)
    a1, a2 = va.attach(), va.attach()
    b1, b2 = vb.attach(), vb.attach()
    batched = va.feed_many({a1: r1, a2: r2})
    solo = {b1: vb.feed(b1, r1), b2: vb.feed(b2, r2)}
    np.testing.assert_array_equal(batched[a1]["spikes"], solo[b1]["spikes"])
    np.testing.assert_array_equal(batched[a2]["spikes"], solo[b2]["spikes"])
    np.testing.assert_array_equal(batched[a1]["output_counts"],
                                  solo[b1]["output_counts"])


def test_model_stream_closed_loop_replay(rng):
    """ModelStream.run_closed_loop (session-level closed loop): replaying
    a fixed encoder stream equals the batch run of the same raster."""
    sess = AcceleratorSession()
    model = sess.deploy("m", _net(rng))
    stream = sess.serve("m", n_slots=2, chunk_steps=4)
    uid = stream.attach()
    raster = (rng.random((6, 6)) < 0.4).astype(np.int32)
    step = {"t": 0}

    def controller(local_spikes):
        step["t"] += 1
        return raster[step["t"]]

    got = stream.run_closed_loop(uid, controller, 6, raster[0])
    from repro.core import cerebra_h
    want = cerebra_h.run(model.program, raster[:, None, :])
    np.testing.assert_array_equal(got["output_counts"],
                                  np.asarray(want["output_counts"])[0])
    lo, hi = stream.phys_slice
    np.testing.assert_array_equal(got["spikes"][:, lo:hi],
                                  np.asarray(want["spikes"])[:, 0, lo:hi])


# --------------------------------------------------------------------------
# Engine chunk-step contract details
# --------------------------------------------------------------------------

def test_step_chunk_shape_validation(rng):
    engine = _engine(rng)
    carry = engine.init_carry(2)
    with pytest.raises(ValueError, match="ext must be"):
        engine.step_chunk(carry, np.zeros((3, 2, 7), np.int32))
    with pytest.raises(ValueError, match="active mask"):
        engine.step_chunk(carry, np.zeros((3, 2, 10), np.int32),
                          np.zeros((3, 3), np.int32))


def test_step_chunk_all_active_equals_run(rng):
    """active=None (or all-ones) is exactly the batch scan."""
    engine = _engine(rng)
    ext = (rng.random((6, 4, 10)) < 0.4).astype(np.int32)
    want = engine.run(ext)
    carry, spikes = engine.step_chunk(engine.init_carry(4), ext)
    np.testing.assert_array_equal(np.asarray(spikes),
                                  np.asarray(want["spikes"]))
    np.testing.assert_array_equal(np.asarray(carry["v"]),
                                  np.asarray(want["v_final"]))


def test_step_chunk_jit_cache_reused(rng):
    engine = _engine(rng)
    ext = (rng.random((4, 2, 10)) < 0.4).astype(np.int32)
    engine.step_chunk(engine.init_carry(2), ext)
    compiled = engine._chunk_jit
    assert compiled is not None
    engine.step_chunk(engine.init_carry(2), ext)
    assert engine._chunk_jit is compiled
