"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device (DESIGN.md: only the dry-run forces 512 placeholder devices)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_random_net(rng, n_in=20, n_neurons=48, density=0.25, out=10,
                    decay_rate=0.25, reset_mode="zero", scale=0.5):
    """Random recurrent-ish SNNetwork with an output slice."""
    from repro.core.lif import LIFParams
    from repro.core.network import SNNetwork

    W = ((rng.random((n_in + n_neurons, n_neurons)) < density)
         * rng.normal(0.0, scale, (n_in + n_neurons, n_neurons)))
    params = LIFParams(decay_rate=decay_rate, threshold=1.0,
                       reset_mode=reset_mode)
    return SNNetwork(
        n_inputs=n_in, n_neurons=n_neurons, weights=W.astype(np.float32),
        params=params, output_slice=(n_neurons - out, n_neurons))


def make_ff_net(rng, sizes=(20, 24, 10), decay_rate=0.25, scale=0.6):
    from repro.core.lif import LIFParams
    from repro.core.network import feedforward

    ws = [rng.normal(0.0, scale / np.sqrt(a), (a, b)).astype(np.float32)
          for a, b in zip(sizes[:-1], sizes[1:])]
    return feedforward(ws, LIFParams(decay_rate=decay_rate))
