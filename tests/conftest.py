"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device (DESIGN.md: only the dry-run forces 512 placeholder devices).

When ``hypothesis`` is not installed, a stub is injected so the property
test modules still collect; every ``@given`` test then skips with a clear
message instead of failing the whole collection run.
"""

import sys
import types

import numpy as np
import pytest

try:  # pragma: no cover - exercised only on machines without hypothesis
    import hypothesis  # noqa: F401
except ImportError:
    _SKIP_REASON = "hypothesis is not installed; property-based test skipped"

    class _StubStrategy:
        """Inert strategy object; supports the chaining API shapes use."""

        def _chain(self, *args, **kwargs):
            return self

        map = filter = flatmap = _chain

        def __call__(self, *args, **kwargs):
            return self

    def _strategy_factory(*args, **kwargs):
        return _StubStrategy()

    def _given(*args, **kwargs):
        def decorate(fn):
            # Bare-varargs signature so pytest never tries to resolve the
            # hypothesis-provided parameters as fixtures.
            def skipper(*a, **k):
                pytest.skip(_SKIP_REASON)

            skipper.__name__ = getattr(fn, "__name__", "property_test")
            skipper.__doc__ = fn.__doc__
            skipper.pytestmark = list(getattr(fn, "pytestmark", []))
            return skipper

        return decorate

    def _settings(*args, **kwargs):
        def decorate(fn):
            return fn

        return decorate

    def _assume(condition):
        return True

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _stub.assume = _assume
    _stub.example = _settings
    _stub.note = lambda *a, **k: None
    _stub.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _strategy_factory
    _stub.strategies = _st
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_random_net(rng, n_in=20, n_neurons=48, density=0.25, out=10,
                    decay_rate=0.25, reset_mode="zero", scale=0.5):
    """Random recurrent-ish SNNetwork with an output slice."""
    from repro.core.lif import LIFParams
    from repro.core.network import SNNetwork

    W = ((rng.random((n_in + n_neurons, n_neurons)) < density)
         * rng.normal(0.0, scale, (n_in + n_neurons, n_neurons)))
    params = LIFParams(decay_rate=decay_rate, threshold=1.0,
                       reset_mode=reset_mode)
    return SNNetwork(
        n_inputs=n_in, n_neurons=n_neurons, weights=W.astype(np.float32),
        params=params, output_slice=(n_neurons - out, n_neurons))


def make_ff_net(rng, sizes=(20, 24, 10), decay_rate=0.25, scale=0.6):
    from repro.core.lif import LIFParams
    from repro.core.network import feedforward

    ws = [rng.normal(0.0, scale / np.sqrt(a), (a, b)).astype(np.float32)
          for a, b in zip(sizes[:-1], sizes[1:])]
    return feedforward(ws, LIFParams(decay_rate=decay_rate))
