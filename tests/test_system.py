"""End-to-end system tests: the full SoC flow (encode -> accelerate ->
decode) and an LM training loop with fault injection on the REDUCED arch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import Shape
from repro.core import coding
from repro.core.session import AcceleratorSession
from repro.data import lm, mnist
from repro.launch.steps import LMHarness
from repro.snn.model import SNNModelConfig
from repro.snn.train import TrainConfig, train
from repro.training.loop import LoopConfig, run_loop


def test_soc_closed_loop(rng):
    """Sensor -> encoder -> Cerebra-H -> decoder -> actuator command.

    The paper's perception-to-action loop: a trained SNN deployed through
    the session API must classify encoded sensor data above chance."""
    cfg = TrainConfig(
        model=SNNModelConfig(layer_sizes=(784, 24, 10)),
        num_steps_time=8, lr=3e-3, batch_size=64, train_steps=60)
    params, _, _ = train(
        cfg, mnist.batches("train", cfg.batch_size, cfg.train_steps, seed=7),
        log_every=0)

    from repro.snn.model import to_snnetwork
    net = to_snnetwork(params, cfg.model)
    sess = AcceleratorSession()
    sess.deploy("digits", net)
    x, y = mnist.load_or_generate("test", 128, seed=2)
    out = sess.run("digits", x, 20, jax.random.key(0))
    acc = float((np.asarray(out["predictions"]) == y).mean())
    assert acc > 0.3  # far above 10% chance through the full HW path


def test_lm_train_loop_with_preemption(tmp_path, rng):
    """REDUCED granite-3-2b: run_loop + AdamW + checkpoint + preemption
    restart reproduces the uninterrupted loss trajectory."""
    mod = configs.get_arch("granite-3-2b")
    cfg = dataclasses.replace(mod.REDUCED, n_layers=2)
    h = LMHarness("granite-3-2b", cfg=cfg)
    model, opt = h.model, h.opt
    params = model.init(jax.random.key(0))
    state0 = {"params": params, "opt": opt.init(params),
              "step": np.asarray(0)}

    @jax.jit
    def step_impl(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        from repro.training.optimizers import apply_updates
        return apply_updates(params, updates), opt_state, loss

    def step_fn(state, batch):
        p, o, loss = step_impl(state["params"], state["opt"], batch)
        return dict(state, params=p, opt=o), {"loss": loss}

    stream = lm.TokenStream(cfg.vocab_size, seed=0)

    def batch_fn(step):
        toks = stream.sample(4, 16, step)
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "targets": jnp.asarray(toks[:, 1:], jnp.int32)}

    ref = run_loop(LoopConfig(total_steps=8, log_every=0),
                   jax.tree.map(lambda x: x, state0), step_fn, batch_fn)

    with pytest.raises(RuntimeError):
        run_loop(LoopConfig(total_steps=8, checkpoint_dir=str(tmp_path),
                            checkpoint_every=3, log_every=0, fail_at_step=5),
                 jax.tree.map(lambda x: x, state0), step_fn, batch_fn)
    out = run_loop(LoopConfig(total_steps=8, checkpoint_dir=str(tmp_path),
                              checkpoint_every=3, log_every=0),
                   jax.tree.map(lambda x: x, state0), step_fn, batch_fn)
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_lm_loss_decreases_on_structured_stream(rng):
    """A few dozen steps on the Markov stream must reduce loss — the data
    pipeline is learnable and gradients flow end to end."""
    mod = configs.get_arch("granite-3-2b")
    cfg = dataclasses.replace(mod.REDUCED, n_layers=2, vocab_size=128)
    h = LMHarness("granite-3-2b", cfg=cfg)
    model = h.model
    from repro.training import optimizers
    opt = optimizers.adamw(3e-3)
    params = model.init(jax.random.key(1))
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return optimizers.apply_updates(params, updates), opt_state, loss

    losses = []
    for s, toks, tgts in lm.lm_batches(cfg.vocab_size, 8, 32, 64, seed=5):
        batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3
