"""SLO watchdog + flight recorder: burn rates, breach edges, post-mortems.

The watchdog evaluates declarative objectives as rolling windows on the
injectable clock and is purely observational — with it (and the flight
recorder, and a tracer) attached, the frontend's output bytes are
pinned identical to a bare run. Breaches are EDGE-triggered: one
counter bump and one callback per transition into breach, no matter how
many evaluations happen while breaching. The flight recorder is a
bounded ring (tracer sink + metric deltas) whose dump is a best-effort
post-mortem: it must never raise out of a crash path.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import DecaySpec, SpikeEngine
from repro.obs import (FlightRecorder, MetricsRegistry, SLObjective,
                       SLOStatus, SLOWatchdog, SpanTracer)
from repro.serving.frontend import AsyncSpikeFrontend
from repro.serving.snn import SpikeServer

THRESH = 1 << 16


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _engine(rng, *, n_in=10, n_phys=16, wmax=1 << 13):
    S = n_in + n_phys
    W = ((rng.random((S, n_phys)) < 0.4)
         * rng.integers(-wmax, wmax, (S, n_phys)))
    return SpikeEngine(jnp.asarray(W, jnp.int32), n_in,
                       decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                       reset_mode="subtract", backend="reference")


def _raster(rng, T, n_in, p=0.35):
    return (rng.random((T, n_in)) < p).astype(np.int32)


# --------------------------------------------------------------------------
# objectives and the watchdog, on a virtual clock
# --------------------------------------------------------------------------

def test_objective_validation():
    with pytest.raises(ValueError, match="unknown SLO kind"):
        SLObjective("x", "latency_p50", 0.1)
    with pytest.raises(ValueError, match="threshold"):
        SLObjective("x", "latency_p99", 0.0)
    with pytest.raises(ValueError, match="window_s"):
        SLObjective("x", "latency_p99", 0.1, window_s=-1.0)
    with pytest.raises(ValueError, match="duplicate"):
        SLOWatchdog([SLObjective("a", "latency_p99", 0.1),
                     SLObjective("a", "queue_depth", 4)])


def test_latency_p99_burn_rate_and_windowing():
    clk = VirtualClock()
    dog = SLOWatchdog([SLObjective("lat", "latency_p99", 0.100,
                                   window_s=10.0)], clock=clk)
    # no data: burn 0, not breached
    s, = dog.check()
    assert s.value is None and s.burn_rate == 0.0 and not s.breached

    for _ in range(10):
        dog.record_done(0.050)
    s, = dog.check()
    assert s.value == pytest.approx(0.050)
    assert s.burn_rate == pytest.approx(0.5)
    assert not s.breached and s.n_samples == 10

    dog.record_done(0.500)           # one slow request breaks p99
    s, = dog.check()
    assert s.burn_rate > 1.0 and s.breached

    clk.t = 11.0                     # the window rolls everything out
    s, = dog.check()
    assert s.value is None and not s.breached


def test_miss_ratio_counts_misses_over_completions():
    clk = VirtualClock()
    dog = SLOWatchdog([SLObjective("miss", "miss_ratio", 0.10,
                                   window_s=60.0)], clock=clk)
    for _ in range(9):
        dog.record_done(0.01)
    dog.record_miss()
    s, = dog.check()
    assert s.value == pytest.approx(0.1)
    assert s.burn_rate == pytest.approx(1.0)
    assert not s.breached            # breach is strictly > 1
    dog.record_miss()
    s, = dog.check()
    assert s.breached and s.n_samples == 11


def test_queue_depth_takes_the_window_max():
    clk = VirtualClock()
    dog = SLOWatchdog([SLObjective("depth", "queue_depth", 4,
                                   window_s=5.0)], clock=clk)
    for d in (1, 5, 2):
        dog.record_queue_depth(d)
    s, = dog.check()
    assert s.value == 5.0 and s.breached
    clk.t = 6.0                      # the depth-5 sample ages out
    dog.record_queue_depth(3)
    s, = dog.check()
    assert s.value == 3.0 and not s.breached


def test_breach_is_edge_triggered_with_registry_and_callbacks():
    clk = VirtualClock()
    reg = MetricsRegistry(clock=clk)
    fired = []
    dog = SLOWatchdog([SLObjective("depth", "queue_depth", 2,
                                   window_s=2.0)],
                      clock=clk, registry=reg, on_breach=fired.append)
    ctr = reg.counter("snn_slo_breaches_total").labels(objective="depth")
    gauge = reg.gauge("snn_slo_burn_rate").labels(objective="depth")

    dog.record_queue_depth(10)
    for _ in range(5):
        dog.check()                  # breaching the whole time
    assert ctr.value == 1            # ONE onset, not five
    assert len(fired) == 1 and isinstance(fired[0], SLOStatus)
    assert gauge.value == pytest.approx(5.0)

    clk.t = 3.0                      # recover...
    dog.check()
    assert gauge.value == 0.0
    dog.record_queue_depth(10)       # ...and breach again: a NEW onset
    dog.check()
    assert ctr.value == 2 and len(fired) == 2


def test_report_is_a_pure_read():
    clk = VirtualClock()
    fired = []
    dog = SLOWatchdog([SLObjective("depth", "queue_depth", 2)],
                      clock=clk, on_breach=fired.append)
    dog.record_queue_depth(9)
    rep = dog.report()
    obj, = rep["objectives"]
    assert obj["breached"] and obj["burn_rate"] == pytest.approx(4.5)
    assert rep["breaches"] == {"depth": 0}   # report() never counts
    assert fired == []                       # ...and never fires
    dog.check()
    assert dog.report()["breaches"] == {"depth": 1}
    assert json.loads(json.dumps(rep))       # summary-embeddable


# --------------------------------------------------------------------------
# frontend wiring, on the virtual clock
# --------------------------------------------------------------------------

def test_frontend_feeds_watchdog_latencies_misses_and_depth(rng):
    engine = _engine(rng)
    clock = VirtualClock()
    dog = SLOWatchdog([SLObjective("lat", "latency_p99", 5.0),
                       SLObjective("miss", "miss_ratio", 0.5),
                       SLObjective("depth", "queue_depth", 50)],
                      clock=clock)
    server = SpikeServer(engine, n_slots=1, chunk_steps=4)
    fe = AsyncSpikeFrontend(server, queue_capacity=8, clock=clock,
                            slo=dog)
    ok = fe.submit(_raster(rng, 4, engine.n_inputs))
    late = fe.submit(_raster(rng, 8, engine.n_inputs), deadline_ms=1_000)
    clock.t = 0.5
    fe.pump()                        # ok served (4 steps = one chunk)
    clock.t = 2.0                    # late's deadline passes while queued
    fe.drain()
    assert ok.state == "done" and late.state == "expired"

    rep = dog.report()
    by = {o["name"]: o for o in rep["objectives"]}
    assert by["lat"]["n_samples"] == 1       # one completion recorded
    assert by["lat"]["value"] == pytest.approx(0.5)
    assert by["miss"]["value"] == pytest.approx(0.5)  # 1 miss / 2
    assert by["depth"]["n_samples"] >= 1     # sampled every round
    assert fe.slo is dog


def test_slo_and_flight_never_change_the_bytes(rng):
    """The whole analysis tier attached — watchdog (with an impossible
    objective, so it breaches), flight recorder, tracer, registry — and
    the served rasters are byte-identical to a bare frontend's."""
    engine = _engine(rng)
    rasters = [_raster(rng, T, engine.n_inputs) for T in (7, 4, 9)]

    def run(instrumented):
        clock = VirtualClock()
        server_kw, fe_kw = {}, {}
        recorder = None
        if instrumented:
            reg = MetricsRegistry(clock=clock)
            recorder = FlightRecorder(clock=clock)
            tracer = SpanTracer(clock=clock, sink=recorder)
            dog = SLOWatchdog(
                [SLObjective("lat", "latency_p99", 1e-9)],  # always hot
                clock=clock, registry=reg,
                on_breach=recorder.on_breach)
            server_kw = dict(metrics=reg, tracer=tracer)
            fe_kw = dict(metrics=reg, tracer=tracer, slo=dog)
        server = SpikeServer(engine, n_slots=2, chunk_steps=3,
                             **server_kw)
        fe = AsyncSpikeFrontend(server, queue_capacity=8, clock=clock,
                                **fe_kw)
        handles = [fe.submit(r) for r in rasters]
        while not fe.idle:
            clock.t += 1.0
            fe.pump()
            if recorder is not None:
                recorder.note_metrics(server.metrics)
        return [h.result()["spikes"] for h in handles], recorder

    bare, _ = run(False)
    full, recorder = run(True)
    for b, f in zip(bare, full):
        np.testing.assert_array_equal(b, f)
    assert recorder.n_dumps >= 1     # the impossible objective breached


# --------------------------------------------------------------------------
# flight recorder
# --------------------------------------------------------------------------

def test_ring_keeps_only_the_last_n_spans():
    clk = VirtualClock()
    rec = FlightRecorder(capacity=3, clock=clk)
    tracer = SpanTracer(clock=clk, sink=rec)
    for i in range(7):
        tracer.event("queued", i, steps=1)
    assert [s["uid"] for s in rec.spans] == [4, 5, 6]
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_note_metrics_records_scalar_deltas_only():
    clk = VirtualClock()
    reg = MetricsRegistry(clock=clk)
    rec = FlightRecorder(clock=clk)
    first = rec.note_metrics(reg)    # every pre-registered scalar
    assert first > 0                 # series is a first sighting...
    assert all(d["delta"] is None for d in rec.deltas)
    assert not any("latency" in d["metric"] for d in rec.deltas)
    assert rec.note_metrics(reg) == 0        # ...then nothing moved

    reg.counter("snn_server_steps_total").inc(5)
    reg.histogram("snn_server_chunk_latency_seconds").observe(0.1)
    assert rec.note_metrics(reg) == 1        # histograms are skipped
    d = rec.deltas[-1]
    assert d["metric"] == "snn_server_steps_total"
    assert d["value"] == 5 and d["delta"] == 5
    reg.counter("snn_server_steps_total").inc(2)
    rec.note_metrics(reg)
    assert rec.deltas[-1]["delta"] == 2


def test_dump_writes_post_mortem_with_inflight_timeline(tmp_path):
    clk = VirtualClock()
    rec = FlightRecorder(clock=clk, path=str(tmp_path / "flight.json"))
    tracer = SpanTracer(clock=clk, sink=rec)
    tracer.event("queued", "a", steps=4)
    tracer.event("admitted", "a", slot=0)    # still running: in-flight

    doc = rec.dump(reason="why-not")
    on_disk = json.load(open(tmp_path / "flight.json"))
    assert on_disk["reason"] == doc["reason"] == "why-not"
    assert len(on_disk["spans"]) == 2
    # the timeline is best-effort: in-flight streams are NOT violations
    assert on_disk["timeline"]["violations"] == []
    assert on_disk["timeline"]["by_state"] == {"running": 1}
    assert rec.n_dumps == 1


def test_armed_dumps_on_crash_and_reraises(tmp_path):
    clk = VirtualClock()
    rec = FlightRecorder(clock=clk)
    tracer = SpanTracer(clock=clk, sink=rec)
    path = tmp_path / "crash.json"
    with pytest.raises(RuntimeError, match="boom"):
        with rec.armed(str(path)):
            tracer.event("queued", "a", steps=1)
            raise RuntimeError("boom")
    doc = json.load(open(path))
    assert doc["reason"] == "crash:RuntimeError"
    assert doc["extra"]["error"] == "boom"
    assert len(doc["spans"]) == 1


def test_on_breach_hook_dumps_with_the_status(tmp_path):
    clk = VirtualClock()
    rec = FlightRecorder(clock=clk, path=str(tmp_path / "breach.json"))
    dog = SLOWatchdog([SLObjective("depth", "queue_depth", 1)],
                      clock=clk, on_breach=rec.on_breach)
    dog.record_queue_depth(99)
    dog.check()
    doc = json.load(open(tmp_path / "breach.json"))
    assert doc["reason"] == "slo-breach:depth"
    assert doc["extra"]["burn_rate"] == pytest.approx(99.0)
    assert rec.n_dumps == 1
    dog.check()                      # still breaching: no second dump
    assert rec.n_dumps == 1
