"""The telemetry layer's hard contract, end to end.

1. Observability READS the datapath and never changes it: a fully
   instrumented stack (server + frontend + connector + session) produces
   byte-identical spikes to a bare one, including across migration.
2. What it reads is TRUE: the server's measured-SOP / source-event /
   weight-block counters must equal the offline ``events.trace``
   accounting on the very same rasters — streaming accounting and batch
   accounting are one semantics.
3. The counters feed the energy model: ``counts_from_registry`` prices a
   live server with the same ``WorkloadCounts`` contract as offline runs.
"""

import numpy as np
import pytest

from repro.core.energy import EnergyModel, counts_from_registry
from repro.core.engine import DecaySpec, SpikeEngine, sources_raster
from repro.core.session import AcceleratorSession
from repro.events.trace import block_traffic, trace_run
from repro.obs import MetricsRegistry, SpanTracer
from repro.serving.connector import InMemoryCarryConnector, migrate_stream
from repro.serving.frontend import AsyncSpikeFrontend
from repro.serving.snn import SpikeServer

from conftest import make_random_net

THRESH = 1 << 16


def make_engine(rng, n_in=12, n_neurons=32, density=0.3, backend="reference"):
    import jax.numpy as jnp

    W = (rng.random((n_in + n_neurons, n_neurons)) < density) * \
        rng.integers(-2**10, 2**10, (n_in + n_neurons, n_neurons))
    return SpikeEngine(jnp.asarray(W, jnp.int32), n_in,
                       decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                       reset_mode="zero", backend=backend)


def rasters(rng, n, T, n_in, p=0.3):
    return [(rng.random((T, n_in)) < p).astype(np.int32) for _ in range(n)]


def feed_all(server, uids, chunks, chunk_steps):
    T = chunks[0].shape[0]
    outs = {u: [] for u in uids}
    for t0 in range(0, T, chunk_steps):
        res = server.feed({u: chunks[i][t0:t0 + chunk_steps]
                           for i, u in enumerate(uids)})
        for u, r in res.items():
            outs[u].append(r["spikes"])
    return {u: np.concatenate(v, axis=0) for u, v in outs.items()}


def test_instrumented_feed_is_byte_identical():
    rng = np.random.default_rng(0)
    engine = make_engine(rng)
    chunks = rasters(rng, 3, 16, engine.n_inputs)

    bare = SpikeServer(engine, n_slots=4, chunk_steps=4)
    inst = SpikeServer(engine, n_slots=4, chunk_steps=4,
                       metrics=MetricsRegistry(), tracer=SpanTracer())
    uids_b = [bare.attach(f"s{i}") for i in range(3)]
    uids_i = [inst.attach(f"s{i}") for i in range(3)]
    out_b = feed_all(bare, uids_b, chunks, 4)
    out_i = feed_all(inst, uids_i, chunks, 4)
    for u in uids_b:
        np.testing.assert_array_equal(out_b[u], out_i[u])


def test_server_counters_match_offline_trace_exactly():
    rng = np.random.default_rng(1)
    engine = make_engine(rng)
    n_streams, T, chunk_steps = 3, 16, 4
    chunks = rasters(rng, n_streams, T, engine.n_inputs)

    reg = MetricsRegistry()
    server = SpikeServer(engine, n_slots=n_streams, chunk_steps=chunk_steps,
                         metrics=reg)
    uids = [server.attach(f"s{i}") for i in range(n_streams)]
    outs = feed_all(server, uids, chunks, chunk_steps)

    # the offline accounting on the same rasters (streams as batch lanes)
    ext = np.stack(chunks, axis=1)
    out = np.stack([outs[u] for u in uids], axis=1)
    rep = trace_run(engine, ext, out)

    c = reg.counter
    assert c("snn_server_steps_total").value == T * n_streams
    assert c("snn_server_chunks_total").value == T // chunk_steps
    assert c("snn_server_spikes_total").value == int(out.sum())
    assert c("snn_server_sops_total").value == rep.measured_sops
    ev = c("snn_server_source_events_total")
    assert (ev.labels(kind="external").value
            + ev.labels(kind="recurrent").value) == rep.source_events
    assert ev.labels(kind="external").value == int(
        (np.asarray(ext) != 0).sum())

    # per-example gate traffic: same block_traffic call trace.py uses
    sources = np.asarray(sources_raster(ext, out))
    touched, dense = block_traffic(sources, tile_batch=1)
    assert c("snn_server_weight_blocks_fetched_total").value == touched
    assert c("snn_server_weight_blocks_dense_total").value == dense

    hist = reg.histogram("snn_server_chunk_latency_seconds") \
        ._require_default()
    assert hist.count == T // chunk_steps


def test_counters_survive_partial_occupancy_and_ragged_chunks():
    rng = np.random.default_rng(2)
    engine = make_engine(rng)
    reg = MetricsRegistry()
    server = SpikeServer(engine, n_slots=4, chunk_steps=4, metrics=reg)
    uid = server.attach("only")
    # ragged: 6 steps through a 4-step chunk server -> chunks of 4 and 2
    raster = (rng.random((6, engine.n_inputs)) < 0.4).astype(np.int32)
    out = np.concatenate([
        server.feed({uid: raster[:4]})[uid]["spikes"],
        server.feed({uid: raster[4:]})[uid]["spikes"],
    ], axis=0)
    rep = trace_run(engine, raster[:, None, :], out[:, None, :])
    c = reg.counter
    assert c("snn_server_steps_total").value == 6
    assert c("snn_server_sops_total").value == rep.measured_sops
    assert c("snn_server_spikes_total").value == int(out.sum())


def test_migration_preserves_bytes_and_counts_ops():
    rng = np.random.default_rng(3)
    engine = make_engine(rng)
    chunks = rasters(rng, 2, 8, engine.n_inputs)

    # bare run for the expected bytes
    bare = SpikeServer(engine, n_slots=4, chunk_steps=4)
    uids = [bare.attach(f"s{i}") for i in range(2)]
    expect = feed_all(bare, uids, chunks, 4)

    reg, tr = MetricsRegistry(), SpanTracer()
    server = SpikeServer(engine, n_slots=4, chunk_steps=4,
                         metrics=reg, tracer=tr)
    for i in range(2):
        server.attach(f"s{i}")
    first = {u: server.feed({u: chunks[i][:4]})[u]["spikes"]
             for i, u in enumerate(("s0", "s1"))}
    # mid-flight slot migration (snapshot -> detach -> attach_stream)
    migrate_stream(server, "s0", slot=3)
    migrate_stream(server, "s1", slot=2)
    second = {u: server.feed({u: chunks[i][4:]})[u]["spikes"]
              for i, u in enumerate(("s0", "s1"))}
    for i, u in enumerate(("s0", "s1")):
        np.testing.assert_array_equal(
            np.concatenate([first[u], second[u]], axis=0), expect[u])

    ops = reg.counter("snn_connector_ops_total")
    assert ops.labels(op="migrate").value == 2
    assert reg.counter("snn_connector_bytes_total") \
        .labels(op="migrate").value > 0
    hist = reg.histogram("snn_connector_op_seconds").labels(op="migrate")
    assert hist.count == 2
    moved = [s for s in tr.spans if s.kind == "migrated"]
    assert [(s.uid, s.attrs["from_slot"], s.attrs["to_slot"])
            for s in moved] == [("s0", 0, 3), ("s1", 1, 2)]


def test_connector_insert_select_count_ops_and_bytes():
    rng = np.random.default_rng(4)
    engine = make_engine(rng)
    reg = MetricsRegistry()
    server = SpikeServer(engine, n_slots=2, chunk_steps=4, metrics=reg)
    uid = server.attach("s0")
    server.feed({uid: rasters(rng, 1, 4, engine.n_inputs)[0]})
    conn = InMemoryCarryConnector().instrument(reg)
    snap = server.snapshot_stream(uid)
    conn.insert("k", snap)
    assert conn.select("k") is not None
    assert conn.select("missing") is None  # miss: no restore recorded
    ops = reg.counter("snn_connector_ops_total")
    assert ops.labels(op="snapshot").value == 1
    assert ops.labels(op="restore").value == 1
    nbytes = reg.counter("snn_connector_bytes_total")
    assert nbytes.labels(op="snapshot").value == len(snap.to_bytes())
    assert nbytes.labels(op="snapshot").value == \
        nbytes.labels(op="restore").value


def test_session_deploy_counters_and_spans():
    rng = np.random.default_rng(5)
    reg, tr = MetricsRegistry(), SpanTracer()
    sess = AcceleratorSession(metrics=reg, tracer=tr)
    sess.deploy("a", make_random_net(rng))
    view = sess.serve("a", n_slots=2, chunk_steps=4)
    uid = view.attach("live")
    view.feed(uid, (rng.random((4, 20)) < 0.3).astype(np.int32))
    sess.deploy("b", make_random_net(rng))  # drains the live stream
    assert reg.counter("snn_session_deploys_total").value == 2
    assert reg.counter("snn_session_redeploys_total").value == 1
    kinds = [s.kind for s in tr.spans]
    assert kinds.count("deploy") == 2
    assert "redeployed" in kinds


def test_frontend_telemetry_mirrors_counts():
    rng = np.random.default_rng(6)
    engine = make_engine(rng)
    reg, tr = MetricsRegistry(), SpanTracer()
    server = SpikeServer(engine, n_slots=2, chunk_steps=4)
    fe = AsyncSpikeFrontend(server, queue_capacity=2, metrics=reg,
                            tracer=tr)
    for r in rasters(rng, 2, 8, engine.n_inputs):
        fe.submit(r)
    fe.drain()
    m = fe.metrics()
    req = reg.counter("snn_frontend_requests_total")
    assert req.labels(outcome="submitted").value == m["counts"]["submitted"]
    assert req.labels(outcome="done").value == m["counts"]["done"] == 2
    assert reg.counter("snn_frontend_rounds_total").value == m["rounds"]
    assert reg.gauge("snn_frontend_queue_depth").value == 0
    done = reg.histogram("snn_frontend_total_seconds") \
        .labels(stream_class="default")
    assert done.count == 2
    retired = [s for s in tr.spans if s.kind == "retired"]
    assert [s.attrs["outcome"] for s in retired] == ["done", "done"]


def test_counts_from_registry_prices_the_live_run():
    rng = np.random.default_rng(7)
    engine = make_engine(rng)
    reg = MetricsRegistry()
    server = SpikeServer(engine, n_slots=2, chunk_steps=4, metrics=reg)
    uids = [server.attach(f"s{i}") for i in range(2)]
    feed_all(server, uids, rasters(rng, 2, 8, engine.n_inputs), 4)

    counts = counts_from_registry(reg)
    assert counts.sops == reg.counter("snn_server_sops_total").value > 0
    assert counts.row_fetches == \
        reg.counter("snn_server_row_fetches_total").value > 0
    assert counts.spike_packets == counts.row_fetches
    # reference-duty cycles: sops at the calibrated model's SOPs/cycle
    per_cycle = EnergyModel.calibrated().reference_rates["sops_per_cycle"]
    assert counts.cycles == pytest.approx(counts.sops / per_cycle)
    bk = EnergyModel.calibrated().breakdown_mw(counts)
    assert bk["total_mw"] > 0
    # explicit cycles override
    assert counts_from_registry(reg, cycles=123.0).cycles == 123.0


def test_closed_loop_counters_match_trace():
    rng = np.random.default_rng(8)
    engine = make_engine(rng)
    reg = MetricsRegistry()
    server = SpikeServer(engine, n_slots=2, chunk_steps=4, metrics=reg)
    uid = server.attach("loop")
    ext0 = (rng.random(engine.n_inputs) < 0.5).astype(np.int32)

    fed = []  # the ext rasters the controller actually injected

    def controller(spikes_t):
        nxt = (rng.random(engine.n_inputs) < 0.3).astype(np.int32)
        fed.append(nxt)
        return nxt

    res = server.run_closed_loop(uid, controller, 6, ext0)
    assert reg.counter("snn_server_steps_total").value == 6
    assert reg.counter("snn_server_spikes_total").value == \
        int(res["spikes"].sum())
    # SOPs agree with the offline trace on the realized ext/out sequence
    # (step t's ext is ext0 for t=0, then what the controller returned)
    ext_seq = np.stack([ext0] + fed[:5], axis=0)
    rep = trace_run(engine, ext_seq[:, None, :],
                    np.asarray(res["spikes"])[:, None, :])
    assert reg.counter("snn_server_sops_total").value == rep.measured_sops
