"""SpikeEngine backend-parity and routing contracts.

The engine is the single functional timestep; every backend must agree
BIT-exactly on the integer path, and both Cerebra frontends must actually
route through it (the Pallas kernel on the real inference path is the
paper's central claim, and PR 1's acceptance criterion).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cerebra_h, cerebra_s
from repro.core import fixedpoint as fxp
from repro.core.engine import (
    MXU_EXACT_BOUND,
    DecaySpec,
    SpikeEngine,
    mxu_partial_sum_bound,
)
from repro.core.mapping import ClusterGeometry

from conftest import make_random_net

THRESH = 1 << 16  # 1.0 in Q16.16

# deliberately ragged (non-block-multiple) shapes: B % 8 != 0, S % 128 != 0
RAGGED_SHAPES = [
    # (B, n_inputs, n_phys)
    (3, 37, 48),
    (1, 1, 1),
    (5, 200, 130),
]


def _random_engine_io(rng, B, n_in, n_phys, T=6, density=0.3, wmax=1 << 14):
    S = n_in + n_phys
    W = (rng.random((S, n_phys)) < density) * rng.integers(
        -wmax, wmax, (S, n_phys))
    ext = (rng.random((T, B, n_in)) < 0.35).astype(np.int32)
    return jnp.asarray(W, jnp.int32), ext


def _run_pair(W, n_in, ext, decay, reset, backend):
    a = SpikeEngine(W, n_in, decay=decay, threshold_raw=THRESH,
                    reset_mode=reset, backend="reference").run(ext)
    b = SpikeEngine(W, n_in, decay=decay, threshold_raw=THRESH,
                    reset_mode=reset, backend=backend).run(ext)
    return a, b


@pytest.mark.parametrize("rate", fxp.SHIFT_DECAY_RATES)
@pytest.mark.parametrize("reset", ["zero", "subtract", "hold"])
def test_backend_parity_shift_decay(rng, rate, reset):
    """reference vs pallas: bit-exact across every reset mode and every
    hardware decay rate (the full Cerebra-H configuration space)."""
    B, n_in, n_phys = 3, 37, 48
    W, ext = _random_engine_io(rng, B, n_in, n_phys)
    ref, pal = _run_pair(W, n_in, ext, DecaySpec.shift(rate), reset, "pallas")
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(pal["spikes"]))
    np.testing.assert_array_equal(np.asarray(ref["v_final"]),
                                  np.asarray(pal["v_final"]))


@pytest.mark.parametrize("B,n_in,n_phys", RAGGED_SHAPES)
def test_backend_parity_ragged_shapes(rng, B, n_in, n_phys):
    """Padding to kernel blocks must never leak into results."""
    W, ext = _random_engine_io(rng, B, n_in, n_phys)
    ref, pal = _run_pair(W, n_in, ext, DecaySpec.shift(0.25), "zero",
                         "pallas")
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(pal["spikes"]))
    np.testing.assert_array_equal(np.asarray(ref["v_final"]),
                                  np.asarray(pal["v_final"]))


@pytest.mark.parametrize("reset", ["zero", "subtract", "hold"])
def test_backend_parity_mul_decay(rng, reset):
    """The Cerebra-S truncating-multiply PDU through the Pallas kernel."""
    B, n_in, n_phys = 3, 20, 24
    W, ext = _random_engine_io(rng, B, n_in, n_phys)
    decay = DecaySpec.mul(int(round(0.7 * 65536)))
    ref, pal = _run_pair(W, n_in, ext, decay, reset, "pallas")
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(pal["spikes"]))
    np.testing.assert_array_equal(np.asarray(ref["v_final"]),
                                  np.asarray(pal["v_final"]))


def test_backend_parity_mxu_within_bound(rng):
    B, n_in, n_phys = 4, 60, 40
    W, ext = _random_engine_io(rng, B, n_in, n_phys, wmax=1 << 13)
    assert mxu_partial_sum_bound(np.asarray(W)) < MXU_EXACT_BOUND
    ref, mxu = _run_pair(W, n_in, ext, DecaySpec.shift(0.5), "zero",
                         "pallas-mxu")
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(mxu["spikes"]))
    np.testing.assert_array_equal(np.asarray(ref["v_final"]),
                                  np.asarray(mxu["v_final"]))


def test_mxu_bound_enforced_at_build_time():
    """A weight image that could overflow the f32 significand must refuse
    to compile for pallas-mxu instead of silently mis-accumulating."""
    n_phys = 8
    n_in = 130  # > one source block, all max-magnitude weights
    W = np.full((n_in + n_phys, n_phys), 1 << 18, np.int32)
    assert mxu_partial_sum_bound(W) >= MXU_EXACT_BOUND
    with pytest.raises(ValueError, match="2\\^24"):
        SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                    threshold_raw=THRESH, reset_mode="zero",
                    backend="pallas-mxu")
    # the exact same program compiles fine on the exact backends
    SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                reset_mode="zero", backend="pallas")


def test_leak_free_if_neuron_supported(rng):
    """beta = 1.0 (decay_rate = 0, a leak-free IF neuron) is a valid
    Cerebra-S configuration: decay_raw = 2^16 is the exact fx_mul
    identity and must compile + run on every backend."""
    net = make_random_net(rng, n_in=6, n_neurons=10, density=0.4,
                          decay_rate=0.0)
    prog = cerebra_s.compile_network(
        net, cerebra_s.CerebraSConfig(n_physical_neurons=16))
    assert prog.decay_raw == 1 << 16
    ext = (rng.random((8, 2, 6)) < 0.4).astype(np.int32)
    ref = cerebra_s.run(prog, ext)
    pal = cerebra_s.run(prog, ext, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(pal["spikes"]))
    # no decay: with hold reset and no input after t0, v must not change
    W = jnp.zeros((3 + 2, 2), jnp.int32)
    eng = SpikeEngine(W, 3, decay=DecaySpec.mul(1 << 16),
                      threshold_raw=THRESH, reset_mode="hold")
    carry = {"v": jnp.asarray([[123, -77]], jnp.int32),
             "spikes": jnp.zeros((1, 2), jnp.int32)}
    carry, _ = eng.step(carry, jnp.zeros((1, 3), jnp.int32))
    np.testing.assert_array_equal(np.asarray(carry["v"]), [[123, -77]])


def test_kernel_decay_misconfiguration_fails_at_build(rng):
    """Forgetting decay_rate for the shift PDU must fail with a pointed
    error at the call site, not a trace-time ValueError in fixedpoint."""
    from repro.kernels import ops

    src = jnp.zeros((2, 8), jnp.int32)
    W = jnp.zeros((8, 8), jnp.int32)
    v = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="decay_rate"):
        ops.spike_timestep(src, W, v, threshold_raw=THRESH)


def test_unknown_backend_rejected(rng):
    W, _ = _random_engine_io(rng, 1, 4, 4)
    with pytest.raises(ValueError, match="backend"):
        SpikeEngine(W, 4, decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                    reset_mode="zero", backend="cuda")


# --------------------------------------------------------------------------
# Frontend routing: the acceptance criterion — Cerebra-H inference with
# backend="pallas" matches the reference raster bit-exactly.
# --------------------------------------------------------------------------

_SMALL_GEOM = ClusterGeometry(n_clusters=4, neurons_per_cluster=4,
                              clusters_per_group=2, rows_per_group=64,
                              clusters_per_l1=2)


def test_cerebra_h_pallas_on_inference_path(rng):
    net = make_random_net(rng, n_in=5, n_neurons=12, density=0.5,
                          decay_rate=0.25)
    prog = cerebra_h.compile_network(
        net, cerebra_h.CerebraHConfig(geometry=_SMALL_GEOM))
    ext = (rng.random((10, 3, 5)) < 0.4).astype(np.int32)
    ref = cerebra_h.run(prog, ext, backend="reference")
    pal = cerebra_h.run(prog, ext, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(pal["spikes"]))
    np.testing.assert_array_equal(np.asarray(ref["output_counts"]),
                                  np.asarray(pal["output_counts"]))
    # the cost model is a pure pass over the raster -> identical accounting
    for k in ("cycles", "sops", "row_fetches"):
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(pal[k]))


def test_cerebra_s_pallas_on_inference_path(rng):
    net = make_random_net(rng, n_in=6, n_neurons=10, density=0.4,
                          decay_rate=0.3, reset_mode="subtract")
    prog = cerebra_s.compile_network(
        net, cerebra_s.CerebraSConfig(n_physical_neurons=16))
    ext = (rng.random((8, 2, 6)) < 0.4).astype(np.int32)
    ref = cerebra_s.run(prog, ext)
    pal = cerebra_s.run(prog, ext, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref["spikes"]),
                                  np.asarray(pal["spikes"]))
    np.testing.assert_array_equal(np.asarray(ref["cycles"]),
                                  np.asarray(pal["cycles"]))


def test_both_generations_route_through_spike_engine(rng):
    """cerebra_s.run and cerebra_h.run share ONE timestep core."""
    netS = make_random_net(rng, n_in=4, n_neurons=8)
    progS = cerebra_s.compile_network(
        netS, cerebra_s.CerebraSConfig(n_physical_neurons=16))
    netH = make_random_net(rng, n_in=4, n_neurons=8)
    progH = cerebra_h.compile_network(
        netH, cerebra_h.CerebraHConfig(geometry=_SMALL_GEOM))
    engS = cerebra_s.make_engine(progS)
    engH = cerebra_h.make_engine(progH)
    assert isinstance(engS, SpikeEngine) and isinstance(engH, SpikeEngine)
    # S kept the fixed-point multiplier; H uses the shift PDU
    assert engS.decay.kind == "mul"
    assert engH.decay.kind == "shift"


def test_per_program_engine_and_jit_caching(rng):
    net = make_random_net(rng, n_in=4, n_neurons=8)
    prog = cerebra_h.compile_network(
        net, cerebra_h.CerebraHConfig(geometry=_SMALL_GEOM))
    e1 = cerebra_h.make_engine(prog, "reference")
    e2 = cerebra_h.make_engine(prog, "reference")
    assert e1 is e2  # one engine per (program, backend)
    ext = (rng.random((4, 2, 4)) < 0.4).astype(np.int32)
    e1.run(ext)
    compiled = e1._run_jit
    assert compiled is not None
    e1.run(ext)
    assert e1._run_jit is compiled  # the compiled scan is reused


# --------------------------------------------------------------------------
# Satellite bugfix regression: ONE initial-membrane-state definition.
# --------------------------------------------------------------------------

def test_initial_membrane_state_unified(rng):
    """Both generations power up with V = 0 (int32 raw, via lif_init) and
    no prior boundary spikes — one definition, pinned here."""
    netS = make_random_net(rng, n_in=4, n_neurons=8)
    progS = cerebra_s.compile_network(
        netS, cerebra_s.CerebraSConfig(n_physical_neurons=16))
    netH = make_random_net(rng, n_in=4, n_neurons=8)
    progH = cerebra_h.compile_network(
        netH, cerebra_h.CerebraHConfig(geometry=_SMALL_GEOM))
    for engine in (cerebra_s.make_engine(progS),
                   cerebra_h.make_engine(progH)):
        carry = engine.init_carry(3)
        assert carry["v"].dtype == jnp.int32
        assert carry["spikes"].dtype == jnp.int32
        assert not np.asarray(carry["v"]).any()
        assert not np.asarray(carry["spikes"]).any()
        # both come from the same method on the same class
        assert type(engine).init_carry is SpikeEngine.init_carry
