"""The bench-regression gate: trajectory joins, thresholds, exit codes.

``scripts/bench_compare.py`` is CI's perf gate, so its own behavior is
pinned: the committed ``BENCH_pr*.json`` trajectory must pass green
(the gate gating the repo must accept the repo), a deliberately
regressed point must fail with exit 1, schema-1 records normalize onto
the schema-2 axis contract, and ``serve_snn --json-summary`` documents
join the trajectory as ``serve_summary`` records.
"""

import copy
import json
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import bench_compare  # noqa: E402

BENCH_FILES = sorted(REPO.glob("BENCH_pr*.json"),
                     key=lambda p: int(p.stem.split("pr")[1]))


def _load_all():
    return [bench_compare.load_doc(p) for p in BENCH_FILES]


def test_committed_trajectory_exists_and_spans_schemas():
    assert len(BENCH_FILES) >= 6, BENCH_FILES
    schemas = {json.load(open(p))["metadata"].get("schema")
               for p in BENCH_FILES}
    assert None in schemas and 2 in schemas  # both eras represented


def test_committed_trajectory_is_green():
    findings = bench_compare.compare(_load_all(), max_time_ratio=5.0)
    bad = [f for f in findings if not f["ok"]]
    assert not bad, bench_compare.render(findings)
    # the join actually compared things across PRs
    assert sum(f["check"] == "us_per_call" for f in findings) >= 10
    assert any(f["check"] == "overhead_frac" for f in findings)
    assert any(f["check"] == "counter_consistent" for f in findings)


def test_cli_green_and_regressed_exit_codes(tmp_path, capsys):
    args = [str(p) for p in BENCH_FILES] + ["--max-time-ratio", "5"]
    assert bench_compare.main(args) == 0
    assert "all green" in capsys.readouterr().out

    # clone the last point, regress a timing 10x and blow the budget
    doc = json.load(open(BENCH_FILES[-1]))
    for rec in doc["results"]:
        if rec.get("us_per_call"):
            rec["us_per_call"] *= 10
        if rec.get("overhead_frac") is not None:
            rec["overhead_frac"] = 0.5
    bad_path = tmp_path / "BENCH_regressed.json"
    bad_path.write_text(json.dumps(doc))
    rc = bench_compare.main(args[:-2] + [str(bad_path),
                                         "--max-time-ratio", "5"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "FAIL" in out and "us_per_call" in out
    assert "overhead_frac" in out


def test_time_ratio_threshold_boundaries():
    prev = {"kind": "kernel", "name": "k", "us_per_call": 100.0}
    cur_ok = {"kind": "kernel", "name": "k", "us_per_call": 199.0}
    cur_bad = {"kind": "kernel", "name": "k", "us_per_call": 201.0}
    mk = bench_compare.normalize_record
    green = bench_compare.compare([("a", [mk(prev)]), ("b", [mk(cur_ok)])])
    assert all(f["ok"] for f in green)
    red = bench_compare.compare([("a", [mk(prev)]), ("b", [mk(cur_bad)])])
    f, = [f for f in red if f["check"] == "us_per_call"]
    assert not f["ok"] and "2.01x" in f["detail"]


def test_ratio_metrics_get_relative_plus_absolute_slack():
    mk = bench_compare.normalize_record

    def pair(p, c):
        prev = mk({"kind": "event_gating", "name": "g",
                   "traffic_ratio": p})
        cur = mk({"kind": "event_gating", "name": "g",
                  "traffic_ratio": c})
        fs = bench_compare.compare([("a", [prev]), ("b", [cur])])
        f, = [f for f in fs if f["check"] == "traffic_ratio"]
        return f["ok"]

    assert pair(0.50, 0.54)          # within 10% relative
    assert not pair(0.50, 0.56)      # beyond both slacks
    # tiny ratios get the absolute floor: 0.01 -> 0.03 is within +0.02
    assert pair(0.01, 0.03)
    assert not pair(0.01, 0.035)


def test_overhead_budget_checks_every_record_not_just_latest():
    mk = bench_compare.normalize_record
    old = mk({"kind": "obs_overhead", "name": "o", "overhead_frac": 0.30})
    new = mk({"kind": "obs_overhead", "name": "o", "overhead_frac": 0.01})
    fs = bench_compare.compare([("a", [old]), ("b", [new])])
    fracs = [f for f in fs if f["check"] == "overhead_frac"]
    assert len(fracs) == 2
    assert [f["ok"] for f in fracs] == [False, True]


def test_schema1_records_normalize_onto_axis_contract():
    label, recs = bench_compare.load_doc(
        min(BENCH_FILES, key=lambda p: int(p.stem.split("pr")[1])))
    for rec in recs:
        for axis in bench_compare.AXES:
            assert axis in rec, (rec.get("name"), axis)
    # a default-filled schema-1 record joins a schema-2 record of the
    # same measurement: same key
    s1 = bench_compare.normalize_record({"kind": "kernel", "name": "k"})
    s2 = bench_compare.normalize_record(
        {"kind": "kernel", "name": "k", "devices": 1, "fuse_steps": 1,
         "backend": None, "gate": None, "batch": None})
    assert bench_compare.record_key(s1) == bench_compare.record_key(s2)


def test_future_schema_is_refused():
    doc = {"metadata": {"schema": bench_compare.SCHEMA_VERSION + 1},
           "results": []}
    with pytest.raises(ValueError, match="newer than this gate"):
        bench_compare.load_doc(doc)
    with pytest.raises(ValueError, match="neither a bench document"):
        bench_compare.load_doc({"what": "ever"})


def test_serve_summary_joins_the_trajectory():
    summary = {
        "mode": "async",
        "steps_per_s": 50_000.0,
        "meta": {"git_commit": "abc123", "bench_schema": 2,
                 "axes": {"backend": "reference", "gate": None,
                          "batch": 8, "devices": 1, "fuse_steps": 1}},
    }
    label, recs = bench_compare.load_doc(summary)
    rec, = recs
    assert rec["kind"] == "serve_summary" and rec["name"] == "serve/async"
    assert rec["us_per_call"] == pytest.approx(20.0)
    assert rec["backend"] == "reference" and rec["batch"] == 8

    # a later summary 10x slower on the same axes must fail the gate
    slow = copy.deepcopy(summary)
    slow["steps_per_s"] = 5_000.0
    fs = bench_compare.compare([bench_compare.load_doc(summary),
                                bench_compare.load_doc(slow)])
    f, = [f for f in fs if f["check"] == "us_per_call"]
    assert not f["ok"]
