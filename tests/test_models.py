"""LM model zoo: per-arch REDUCED smoke tests (forward/train step on CPU,
shape + finiteness), and prefill/decode vs teacher-forced consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import Shape
from repro.launch.steps import LMHarness

SMOKE = Shape("smoke", 32, 2, "train")
ARCHS = configs.list_archs()


def _batch_for(h, shape, rng):
    out = {}
    for k, sds in h.batch_shapes(shape).items():
        if sds.dtype == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, min(h.cfg.vocab_size, 100), sds.shape),
                jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 0.02, sds.shape), sds.dtype)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_grads(arch, rng):
    """One forward + one backward on the REDUCED config: correct shapes,
    no NaNs anywhere (the per-arch smoke test the assignment requires)."""
    mod = configs.get_arch(arch)
    h = LMHarness(arch, cfg=mod.REDUCED)
    params = h.model.init(jax.random.key(0))
    batch = _batch_for(h, SMOKE, rng)

    loss, aux = h.model.loss(params, batch)
    assert np.isfinite(float(loss))

    grads = jax.grad(lambda p: h.model.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert any(float(jnp.abs(g.astype(jnp.float32)).sum()) > 0
               for g in leaves)

    logits, _ = h.model.forward(params, batch)
    B, S = batch["targets"].shape
    assert logits.shape == (B, S if arch != "whisper-large-v3" else S,
                            h.cfg.vocab_size)


# ---------------------------------------------------------------------------
# Decode-path consistency: prefill + step-by-step decode must reproduce the
# teacher-forced logits (catches cache indexing / rope / window bugs).
# ---------------------------------------------------------------------------
DECODE_ARCHS = ["granite-3-2b", "mixtral-8x7b", "minicpm3-4b",
                "zamba2-1.2b", "rwkv6-7b", "gemma3-12b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    mod = configs.get_arch(arch)
    cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32)
    if cfg.moe is not None:
        # capacity_factor = E/k makes capacity dispatch exactly dropless so
        # teacher-forced and incremental paths are comparable (decode steps
        # are dropless by construction; GShard prefill/train may drop)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=cfg.moe.n_experts / cfg.moe.top_k))
    h = LMHarness(arch, cfg=cfg)
    model = h.model
    params = model.init(jax.random.key(1))
    B, S, k = 2, 12, 6
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)

    full_logits, _ = model.forward(params, {"tokens": toks})

    cache = model.init_cache(B, S)
    logits_k, cache = model.prefill(params, {"tokens": toks[:, :k]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits_k[:, 0]), np.asarray(full_logits[:, k - 1]),
        rtol=2e-3, atol=2e-3)

    for pos in range(k, S):
        step_logits, cache = model.decode_step(
            params, {"tokens": toks[:, pos:pos + 1]}, jnp.int32(pos), cache)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, pos]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} decode divergence at pos {pos}")


def test_sliding_window_ring_cache_long_decode(rng):
    """Mixtral REDUCED has window 8: decoding past the window must still
    match teacher forcing (ring-buffer overwrite correctness)."""
    mod = configs.get_arch("mixtral-8x7b")
    cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=cfg.moe.n_experts / cfg.moe.top_k))
    model = configs.get_arch("mixtral-8x7b").build(cfg)
    params = model.init(jax.random.key(2))
    B, S = 1, 24  # 3x the window
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S)
    _, cache = model.prefill(params, {"tokens": toks[:, :4]}, cache)
    for pos in range(4, S):
        step_logits, cache = model.decode_step(
            params, {"tokens": toks[:, pos:pos + 1]}, jnp.int32(pos), cache)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=2e-3, atol=2e-3)


def test_whisper_encdec_paths(rng):
    mod = configs.get_arch("whisper-large-v3")
    cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32)
    h = LMHarness("whisper-large-v3", cfg=cfg)
    model = h.model
    params = model.init(jax.random.key(3))
    B, F, S = 2, 8, 10
    enc = jnp.asarray(rng.normal(0, 0.1, (B, F, cfg.d_model)), jnp.float32)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"enc_embeds": enc, "tokens": toks, "targets": toks}
    loss, _ = model.loss(params, batch)
    assert np.isfinite(float(loss))

    full_logits, _ = model.forward(params, batch)
    cache = model.init_cache(B, S, F)
    k = 4
    logits_k, cache = model.prefill(
        params, {"enc_embeds": enc, "tokens": toks[:, :k]}, cache)
    np.testing.assert_allclose(np.asarray(logits_k[:, 0]),
                               np.asarray(full_logits[:, k - 1]),
                               rtol=2e-3, atol=2e-3)
    for pos in range(k, S):
        step_logits, cache = model.decode_step(
            params, {"tokens": toks[:, pos:pos + 1]}, jnp.int32(pos), cache)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, pos]),
            rtol=2e-3, atol=2e-3)


def test_qwen2vl_mrope_changes_logits(rng):
    """M-RoPE position stream must influence attention (not a no-op)."""
    mod = configs.get_arch("qwen2-vl-2b")
    cfg = dataclasses.replace(mod.REDUCED, dtype=jnp.float32)
    model = configs.get_arch("qwen2-vl-2b").build(cfg)
    params = model.init(jax.random.key(4))
    B, S = 1, 8
    emb = jnp.asarray(rng.normal(0, 0.05, (B, S, cfg.d_model)), jnp.float32)
    tgt = jnp.zeros((B, S), jnp.int32)
    pos_a = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
    pos_b = pos_a.at[1:].multiply(3)  # different spatial ids
    la, _ = model.forward({**params}, {"embeds": emb, "targets": tgt,
                                       "mrope_positions": pos_a})
    lb, _ = model.forward({**params}, {"embeds": emb, "targets": tgt,
                                       "mrope_positions": pos_b})
    assert not np.allclose(np.asarray(la), np.asarray(lb))


def test_moe_router_balance_aux(rng):
    """MoE aux loss exists and is positive (load-balance term wired in)."""
    mod = configs.get_arch("mixtral-8x7b")
    h = LMHarness("mixtral-8x7b", cfg=mod.REDUCED)
    params = h.model.init(jax.random.key(5))
    batch = _batch_for(h, SMOKE, rng)
    _, aux = h.model.forward(params, batch)
    assert float(aux) > 0.0


def test_param_count_analytics():
    """Analytic 6ND param counts are close to the actual leaf totals."""
    for arch in ("granite-3-2b", "mixtral-8x7b", "rwkv6-7b"):
        mod = configs.get_arch(arch)
        h = LMHarness(arch, cfg=mod.REDUCED)
        shapes = h.param_shapes()
        actual = sum(int(np.prod(s.shape))
                     for s in jax.tree.leaves(shapes))
        analytic = h.cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, arch
        if h.cfg.moe:
            assert h.cfg.active_param_count() < analytic
