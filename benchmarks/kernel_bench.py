"""Kernel micro-benchmarks: the fused accelerator timestep vs its unfused
reference, at the paper's 1024-neuron scale (CPU wall time is NOT the
deliverable — the structural claim is the event-gated kernel touches fewer
weight blocks; timings are still printed for regression tracking).

``--backend`` additionally benchmarks the full SpikeEngine scan per
backend, so the Pallas-vs-reference speedup is measurable on real
inference timesteps (one engine, carries included) rather than only on
the isolated kernel call.

``--devices N`` (optionally with ``--mesh KNxKB``) adds the scale-out
axis: every engine-scan and streaming bench also runs on a mesh-sharded
``MeshSpikeEngine`` (N faked host devices on CPU; real devices on TPU),
so the per-timestep cost of the neuron-shard spike exchange is tracked
next to the single-device numbers.

``--sparsity S1,S2,...`` adds the event-gating axis: gated-vs-dense
weight-block traffic and SOP reduction (measured from real rasters via
``events.trace``) per gate granularity (batch-tile vs per-example, the
batch-tile=1 serving mode) x backend x serving occupancy.

``--async`` adds the front-door axis: the ``AsyncSpikeFrontend`` request
queue driven open-loop at under/overload on a virtual clock — outcome
counts (done/rejected/dropped/expired), queue depth, and queue-wait vs
service percentiles per backpressure policy (BENCH_pr5.json).

``--fuse-steps K1,K2,...`` adds the K-step fusion axis (BENCH_pr6.json):
engine-scan steps/s and weight-block traffic per K x backend x sparsity x
serving occupancy. Traffic is counted twice and cross-checked — the
kernel-side gate scalars (``ops.ext_gate_activity``, the DMAs the fused
kernel actually issues) against the ``events.trace`` window-OR model —
so the ~1/K per-step traffic claim is measured, not estimated.

``--json out.json`` writes all results as machine-readable records per
(backend, batch, occupancy, sparsity, gate, devices, fuse_steps) — the
repo's ``BENCH_*.json`` perf trajectory (schema versioned in
``benchmarks/common.py``; every record carries every axis).
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_call
from repro.core.engine import (BACKENDS, GATES, DecaySpec, SpikeEngine,
                               sources_raster)
from repro.distributed.spike_mesh import (ensure_host_devices,
                                          make_spike_mesh, parse_mesh_spec)
from repro.events import trace
from repro.serving.frontend import AsyncSpikeFrontend
from repro.serving.snn import SpikeServer

# NOTE: repro.kernels.ops/ref import the Pallas TPU machinery, which
# INITIALIZES the XLA backend at import time — that would lock in the
# device count before --devices can force faked host devices. They are
# imported inside main(), after ensure_host_devices().


def bench_engine_backends(backends, *, batch: int, activity: float,
                          steps: int = 4, mesh=None) -> None:
    """Per-backend engine-scan throughput at the 1024-neuron scale."""
    devices = 1 if mesh is None else mesh.size
    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    ext = jnp.asarray(
        rng.random((steps, batch, n_in)) < activity, jnp.int32)
    for backend in backends:
        engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero",
                             backend=backend)
        if mesh is not None:
            engine = engine.to_mesh(mesh)
        t_run = time_call(lambda e=engine: e.run(ext)["spikes"])
        per_step = t_run / steps
        emit(f"engine/timestep_{backend}_d{devices}", per_step,
             f"us/timestep B={batch} S={n_in + P} P={P} "
             f"activity={activity} T={steps} devices={devices}",
             kind="engine_scan", backend=backend, gate=engine.gate,
             batch=batch, activity=activity, devices=devices,
             per_timestep=True)


def bench_streaming(backends, *, n_slots: int, activity: float,
                    chunk_steps: int = 8, rounds: int = 3,
                    mesh=None) -> None:
    """The serving axis: masked slot-batch chunk step (SpikeServer.feed)
    vs the one-shot batch scan on the same raster, plus the cost of a
    partially occupied slot batch (the serving occupancy regime)."""
    devices = 1 if mesh is None else mesh.size
    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    T = chunk_steps * rounds
    rasters = [
        (rng.random((T, n_in)) < activity).astype(np.int32)
        for _ in range(n_slots)
    ]
    batch = jnp.asarray(np.stack(rasters, axis=1))  # (T, n_slots, n_in)
    for backend in backends:
        engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero",
                             backend=backend)
        if mesh is not None:
            engine = engine.to_mesh(mesh)
        t_batch = time_call(lambda e=engine: e.run(batch)["spikes"])
        emit(f"streaming/batch_scan_{backend}_d{devices}", t_batch / T,
             f"us/timestep B={n_slots} T={T} devices={devices} "
             f"(one-shot run)",
             kind="streaming_batch_scan", backend=backend,
             gate=engine.gate, batch=n_slots, activity=activity,
             devices=devices, per_timestep=True)

        for occupancy in (1.0, 0.25):
            n_live = max(1, int(round(occupancy * n_slots)))

            def serve(e=engine, n_live=n_live):
                srv = SpikeServer(e, n_slots=n_slots,
                                  chunk_steps=chunk_steps)
                uids = [srv.attach() for _ in range(n_live)]
                for t0 in range(0, T, chunk_steps):
                    srv.feed({u: rasters[i][t0:t0 + chunk_steps]
                              for i, u in enumerate(uids)})
                return srv.total_steps

            t_srv = time_call(serve)
            emit(f"streaming/feed_{backend}_occ{occupancy:g}_d{devices}",
                 t_srv / T,
                 f"us/timestep {n_live}/{n_slots} slots live, "
                 f"chunk={chunk_steps} devices={devices} "
                 f"(masked step, per-chunk host hop)",
                 kind="streaming_feed", backend=backend, gate=engine.gate,
                 batch=n_slots, occupancy=occupancy, activity=activity,
                 devices=devices, per_timestep=True)


def bench_event_gating(backends, sparsities, *, batch: int,
                       n_slots: int = 8, steps: int = 4) -> None:
    """The sparsity axis: event-gated vs dense work, from real rasters.

    For each source-activity level this records (a) the gated-vs-dense
    weight-block traffic and SOP reduction under both gate granularities
    (accounting via ``events.trace`` — the structural claim), (b) the
    engine-scan time per backend x gate, and (c) the serving occupancy
    regime: a slot batch with idle slots, where the batch-tile=1
    (per-example) gate skips the idle slots' weight traffic entirely
    while the batch-tile OR cannot.
    """
    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    ref = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                      threshold_raw=1 << 16, reset_mode="zero")
    for sparsity in sparsities:
        ext = jnp.asarray(
            rng.random((steps, batch, n_in)) < sparsity, jnp.int32)
        rep = trace.trace_run(ref, ext, ref.run(ext)["spikes"])
        for gate in GATES:
            touched, total = rep.blocks[gate]
            emit(f"gating/traffic_{gate}_s{sparsity:g}", None,
                 f"{touched}/{total} weight blocks "
                 f"({100 * rep.traffic_ratio(gate):.1f}% of dense), "
                 f"SOPs {100 * rep.sop_ratio:.1f}% of dense, B={batch}",
                 kind="event_gating", gate=gate, sparsity=sparsity,
                 batch=batch, blocks_touched=touched, blocks_total=total,
                 traffic_ratio=round(rep.traffic_ratio(gate), 4),
                 measured_sops=rep.measured_sops,
                 dense_sops=rep.dense_sops,
                 sop_ratio=round(rep.sop_ratio, 4))
        for backend in backends:
            # the gate is a kernel concept: the reference matmul ignores
            # it, so timing reference x per-example would record pure jit
            # noise as a gate effect — one row there.
            for gate in (GATES if backend != "reference"
                         else ("batch-tile",)):
                engine = SpikeEngine(
                    W, n_in, decay=DecaySpec.shift(0.25),
                    threshold_raw=1 << 16, reset_mode="zero",
                    backend=backend, gate=gate)
                t = time_call(lambda e=engine: e.run(ext)["spikes"])
                emit(f"gating/timestep_{backend}_{gate}_s{sparsity:g}",
                     t / steps,
                     f"us/timestep B={batch} sparsity={sparsity} "
                     f"gate={gate}",
                     kind="event_gating_time", backend=backend, gate=gate,
                     sparsity=sparsity, batch=batch, per_timestep=True)
        # serving occupancy: only a fraction of slots carry a live stream
        # (idle slots are silent end-to-end — no input, no spikes)
        for occupancy in (1.0, 0.25, 0.125):
            n_live = max(1, int(round(occupancy * n_slots)))
            slot_ext = np.zeros((steps, n_slots, n_in), np.int32)
            slot_ext[:, :n_live] = np.asarray(
                rng.random((steps, n_live, n_in)) < sparsity, np.int32)
            srep = trace.trace_run(
                ref, slot_ext, ref.run(jnp.asarray(slot_ext))["spikes"])
            for gate in GATES:
                touched, total = srep.blocks[gate]
                emit(f"gating/serving_{gate}_occ{occupancy:g}"
                     f"_s{sparsity:g}", None,
                     f"{n_live}/{n_slots} slots live: {touched}/{total} "
                     f"weight blocks "
                     f"({100 * srep.traffic_ratio(gate):.1f}% of dense)",
                     kind="event_gating_serving", gate=gate,
                     occupancy=occupancy, sparsity=sparsity,
                     n_slots=n_slots, blocks_touched=touched,
                     blocks_total=total,
                     traffic_ratio=round(srep.traffic_ratio(gate), 4))


def bench_fuse_steps(backends, fuse_list, sparsities, *, batch: int,
                     n_slots: int = 8, steps: int = 8) -> None:
    """The K-step fusion axis: per-step weight traffic shrinking ~1/K.

    For each sparsity level this records (a) the fused kernel's
    weight-block traffic per K from the ``events.trace`` window-OR model,
    CROSS-CHECKED against the gate scalars the kernel actually DMAs by
    (``ops.ext_gate_activity`` — the two counters must agree exactly, or
    this bench raises), (b) engine-scan time per backend x K (the
    reference backend has no fused path — ``SpikeEngine`` carries K but
    executes per step — so it is timed once at K=1 as the baseline), and
    (c) the serving occupancy regime: fused per-example (tile_batch=1)
    traffic on a slot batch with idle slots.
    """
    from repro.kernels import ops  # deferred: see NOTE at module top

    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    ref_engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero")
    for sparsity in sparsities:
        ext = jnp.asarray(
            rng.random((steps, batch, n_in)) < sparsity, jnp.int32)
        out = ref_engine.run(ext)["spikes"]
        sources = np.asarray(sources_raster(ext, out))
        for K in fuse_list:
            touched, total = trace.fused_block_traffic(
                sources, n_in, fuse_steps=K)
            # counter cross-check: the trace model's window-OR count of
            # EXT blocks must equal the number of nonzero gate scalars
            # the fused kernel schedules DMAs from
            ext_trace = trace.block_traffic(
                np.asarray(ext), fuse_steps=K)[0]
            ext_kernel = int(
                (np.asarray(ops.ext_gate_activity(ext, fuse_steps=K))
                 > 0).sum())
            if ext_kernel != ext_trace:
                raise AssertionError(
                    f"fused traffic counters disagree at K={K}: kernel "
                    f"gate scalars say {ext_kernel} ext-block DMAs, "
                    f"trace window-OR says {ext_trace}")
            emit(f"fusion/traffic_K{K}_s{sparsity:g}", None,
                 f"{touched}/{total} weight blocks "
                 f"({100 * touched / max(total, 1):.1f}% of per-step "
                 f"dense), {ext_kernel} gated ext DMAs "
                 f"(counter-checked), B={batch} T={steps}",
                 kind="fusion_traffic", fuse_steps=K, sparsity=sparsity,
                 batch=batch, blocks_touched=touched, blocks_total=total,
                 traffic_ratio=round(touched / max(total, 1), 4),
                 ext_gate_dmas=ext_kernel, counter_consistent=True)
        for backend in backends:
            for K in (fuse_list if backend != "reference" else [1]):
                engine = SpikeEngine(
                    W, n_in, decay=DecaySpec.shift(0.25),
                    threshold_raw=1 << 16, reset_mode="zero",
                    backend=backend, fuse_steps=K)
                t = time_call(lambda e=engine: e.run(ext)["spikes"])
                emit(f"fusion/timestep_{backend}_K{K}_s{sparsity:g}",
                     t / steps,
                     f"us/timestep B={batch} sparsity={sparsity} K={K} "
                     f"gate={engine.gate}",
                     kind="fusion_time", backend=backend, fuse_steps=K,
                     gate=engine.gate, sparsity=sparsity, batch=batch,
                     per_timestep=True)
        # serving occupancy: idle slots under the per-example fused gate
        # (tile_batch=1 — a silent slot's ext blocks never DMA)
        for occupancy in (1.0, 0.25):
            n_live = max(1, int(round(occupancy * n_slots)))
            slot_ext = np.zeros((steps, n_slots, n_in), np.int32)
            slot_ext[:, :n_live] = np.asarray(
                rng.random((steps, n_live, n_in)) < sparsity, np.int32)
            slot_out = ref_engine.run(jnp.asarray(slot_ext))["spikes"]
            slot_src = np.asarray(sources_raster(slot_ext, slot_out))
            for K in fuse_list:
                touched, total = trace.fused_block_traffic(
                    slot_src, n_in, fuse_steps=K, tile_batch=1)
                emit(f"fusion/serving_K{K}_occ{occupancy:g}"
                     f"_s{sparsity:g}", None,
                     f"{n_live}/{n_slots} slots live: {touched}/{total} "
                     f"weight blocks "
                     f"({100 * touched / max(total, 1):.1f}% of per-step "
                     f"dense)",
                     kind="fusion_serving", fuse_steps=K,
                     gate="per-example", occupancy=occupancy,
                     sparsity=sparsity, n_slots=n_slots,
                     blocks_touched=touched, blocks_total=total,
                     traffic_ratio=round(touched / max(total, 1), 4))


def bench_async_frontend(backends, *, n_slots: int = 8,
                         chunk_steps: int = 8, n_requests: int = 24,
                         T: int = 32, activity: float = 0.05,
                         queue_capacity: int = 6) -> None:
    """The async front-door axis: admission queue vs the step loop.

    Drives :class:`AsyncSpikeFrontend` on a VIRTUAL clock (1 unit per
    pump round) so the queue dynamics are deterministic: requests arrive
    open-loop at ``load_factor`` x the slot service rate (``n_slots *
    chunk_steps / T`` streams per round at full occupancy). Underload
    (0.5x) shows the queue staying shallow; overload (2x) shows depth
    growth until backpressure (reject / drop-oldest) or a deadline sheds
    load. Wall time over the whole run gives the served steps/s next to
    the per-regime outcome counts and queue-wait / service percentiles
    (in pump rounds — the virtual clock's unit).
    """
    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    rasters = [(rng.random((T, n_in)) < activity).astype(np.int32)
               for _ in range(n_requests)]
    service_rate = n_slots * chunk_steps / T  # streams retired per round
    regimes = [(0.5, "reject", None), (2.0, "reject", None),
               (2.0, "drop-oldest", None), (2.0, "reject", 3.0)]
    for backend in backends:
        engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero",
                             backend=backend)
        for load, policy, deadline_rounds in regimes:
            server = SpikeServer(engine, n_slots=n_slots,
                                 chunk_steps=chunk_steps)
            t_virtual = [0.0]
            fe = AsyncSpikeFrontend(
                server, queue_capacity=queue_capacity, backpressure=policy,
                deadline_ms=(None if deadline_rounds is None
                             else deadline_rounds * 1e3),
                clock=lambda t=t_virtual: t[0])
            arrive_at = [i / (load * service_rate)
                         for i in range(n_requests)]
            i = 0
            t0 = time.perf_counter()
            while i < n_requests or not fe.idle:
                while i < n_requests and arrive_at[i] <= t_virtual[0]:
                    fe.submit(rasters[i])
                    i += 1
                fe.pump()
                t_virtual[0] += 1.0
            wall = time.perf_counter() - t0
            m = fe.metrics()
            c = m["counts"]
            dl = ("" if deadline_rounds is None
                  else f"_dl{deadline_rounds:g}")
            emit(f"async/frontend_{backend}_load{load:g}_{policy}{dl}",
                 wall * 1e6 / max(server.total_steps, 1),
                 f"{c.get('done', 0)}/{n_requests} done, "
                 f"{c.get('rejected', 0)} rej, {c.get('dropped', 0)} drop, "
                 f"{c.get('expired', 0)} exp, queue depth max "
                 f"{m['queue_depth']['max']}/{queue_capacity}, "
                 f"offered {load:g}x service rate",
                 kind="async_frontend", backend=backend, load_factor=load,
                 policy=policy, deadline_rounds=deadline_rounds,
                 n_requests=n_requests, n_slots=n_slots,
                 queue_capacity=queue_capacity,
                 done=c.get("done", 0), rejected=c.get("rejected", 0),
                 dropped=c.get("dropped", 0), expired=c.get("expired", 0),
                 queue_depth_max=m["queue_depth"]["max"],
                 queue_wait_p50_rounds=m["queue_wait"]["p50"],
                 queue_wait_p95_rounds=m["queue_wait"]["p95"],
                 service_p50_rounds=m["service"]["p50"],
                 per_timestep=True)


def bench_qos_frontend(backends, *, n_slots: int = 4, chunk_steps: int = 8,
                       T: int = 32, n_bg: int = 16, n_hi: int = 8,
                       activity: float = 0.05) -> None:
    """The multi-tenant QoS axis: per-class latency isolation.

    Drives the SAME adversarial 2-class traffic plan through three front
    doors on a virtual clock (1 unit per pump round): a background class
    trickling in at the slot service rate while a bursty class lands all
    its requests at once mid-run. ``fifo`` ignores the classes (the
    PR 5 baseline — the burst waits behind the backlog), ``wfq`` ranks
    the burst class into a higher priority stratum with a 4x weight, and
    ``preempt`` additionally sheds running background streams through
    the connector. Per-class p99 total latency (in rounds) is the
    deliverable: the QoS claim — high-priority p99 strictly below the
    FIFO baseline at the SAME offered load — is ENFORCED on the
    reference backend (deterministic virtual-clock schedule), not just
    recorded.
    """
    from repro.serving.connector import InMemoryCarryConnector
    from repro.serving.qos import QoSClass, QoSPolicy

    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    rasters = [(rng.random((T, n_in)) < activity).astype(np.int32)
               for _ in range(n_bg + n_hi)]
    # deterministic plan: the background class arrives at 2x the slot
    # service rate (n_slots*chunk_steps/T = 1 stream per round here), so
    # a backlog is already deep when every hi request lands at once at
    # round 6 — FIFO makes the burst wait behind that backlog; QoS must
    # not
    plan = sorted([(0.5 * i, "bg", rasters[i]) for i in range(n_bg)]
                  + [(6.0, "hi", rasters[n_bg + i])
                     for i in range(n_hi)], key=lambda e: e[0])
    scenarios = [
        ("fifo", None),
        ("wfq", QoSPolicy(classes={"hi": QoSClass(priority=1, weight=4),
                                   "bg": QoSClass(priority=0, weight=1)})),
        ("preempt", QoSPolicy(
            classes={"hi": QoSClass(priority=1, weight=4),
                     "bg": QoSClass(priority=0, weight=1)},
            preempt=True)),
    ]
    for backend in backends:
        engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero",
                             backend=backend)
        fifo_hi_p99 = None
        for scenario, policy in scenarios:
            server = SpikeServer(engine, n_slots=n_slots,
                                 chunk_steps=chunk_steps)
            t_virtual = [0.0]
            fe = AsyncSpikeFrontend(
                server, queue_capacity=n_bg + n_hi + 1,
                clock=lambda t=t_virtual: t[0], qos=policy,
                connector=(InMemoryCarryConnector()
                           if policy is not None and policy.preempt
                           else None))
            i = 0
            t0 = time.perf_counter()
            while i < len(plan) or not fe.idle:
                while i < len(plan) and plan[i][0] <= t_virtual[0]:
                    fe.submit(plan[i][2], tenant=plan[i][1])
                    i += 1
                fe.pump()
                t_virtual[0] += 1.0
            wall = time.perf_counter() - t0
            m = fe.metrics()
            hi, bg = m["by_class"]["hi"], m["by_class"]["bg"]
            hi_p99, bg_p99 = hi["total"]["p99"], bg["total"]["p99"]
            if scenario == "fifo":
                fifo_hi_p99 = hi_p99
            emit(f"qos/frontend_{backend}_{scenario}",
                 wall * 1e6 / max(server.total_steps, 1),
                 f"hi p99 {hi_p99:g} rounds vs bg {bg_p99:g} (fifo hi "
                 f"{fifo_hi_p99:g}); {m['counts']['done']}/{len(plan)} "
                 f"done, {m['counts']['evicted']} preempted",
                 kind="qos_frontend", backend=backend, scenario=scenario,
                 n_requests=len(plan), n_slots=n_slots,
                 chunk_steps=chunk_steps,
                 hi_p99_rounds=hi_p99, bg_p99_rounds=bg_p99,
                 hi_p50_rounds=hi["total"]["p50"],
                 bg_p50_rounds=bg["total"]["p50"],
                 fifo_hi_p99_rounds=fifo_hi_p99,
                 done=m["counts"]["done"],
                 evicted=m["counts"]["evicted"],
                 parked=m["counts"]["parked"],
                 per_timestep=True)
            if (backend == "reference" and scenario != "fifo"
                    and not hi_p99 < fifo_hi_p99):
                raise SystemExit(
                    f"QoS isolation claim failed: {scenario} hi-class "
                    f"p99 {hi_p99:g} rounds is not strictly below the "
                    f"FIFO baseline {fifo_hi_p99:g} at the same offered "
                    f"load")


def bench_migration(backends, *, n_slots: int = 8, chunk_steps: int = 8,
                    activity: float = 0.05) -> None:
    """The migration-overhead axis: what a stream-state move costs.

    The connector's contract is exactness (a migrated raster is
    byte-identical); this bench records its PRICE next to the work a
    migration displaces: per-stream snapshot latency, a full in-memory
    detach->attach round trip, the same round trip through a file-backed
    connector (one fsync-less atomic write + read), and the serialized
    blob size — against the cost of the ``chunk_steps`` feed quantum the
    slot would have run in that time. Spill/restore being cheap relative
    to a service quantum is what makes slot count stop bounding
    concurrent streams.
    """
    import tempfile

    from repro.serving.connector import (FileCarryConnector,
                                         InMemoryCarryConnector)

    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    chunk = (rng.random((chunk_steps, n_in)) < activity).astype(np.int32)
    for backend in backends:
        engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero",
                             backend=backend)
        server = SpikeServer(engine, n_slots=n_slots,
                             chunk_steps=chunk_steps)
        uids = [server.attach(f"s{i}") for i in range(n_slots - 1)]
        server.feed({uid: chunk for uid in uids})  # warm carries + XLA
        uid = uids[0]
        t_feed = time_call(
            lambda: server.feed({u: chunk for u in uids})[uid]["spikes"])
        snap = server.snapshot_stream(uid)
        blob_bytes = len(snap.to_bytes())
        t_snap = time_call(lambda: server.snapshot_stream(uid).to_bytes())

        mem = InMemoryCarryConnector()

        def roundtrip(conn):
            server.detach_stream(uid, conn)
            server.attach_stream(conn, uid)
            return server.carry["v"]

        t_mem = time_call(lambda: roundtrip(mem))
        with tempfile.TemporaryDirectory() as d:
            disk = FileCarryConnector(d)
            t_disk = time_call(lambda: roundtrip(disk))
        emit(f"migration/roundtrip_{backend}", t_mem,
             f"snapshot {t_snap:.0f} us, mem move {t_mem:.0f} us, file "
             f"move {t_disk:.0f} us, blob {blob_bytes} B vs "
             f"{chunk_steps}-step feed quantum {t_feed:.0f} us",
             kind="migration", backend=backend, n_slots=n_slots,
             snapshot_us=round(t_snap, 2), roundtrip_mem_us=round(t_mem, 2),
             roundtrip_file_us=round(t_disk, 2), blob_bytes=blob_bytes,
             feed_quantum_us=round(t_feed, 2),
             migration_vs_quantum=round(t_mem / max(t_feed, 1e-9), 4))


def bench_obs_overhead(backends, *, n_slots: int = 8, chunk_steps: int = 8,
                       rounds: int = 6, activity: float = 0.05,
                       budget: float = 0.05) -> None:
    """The observability-overhead axis: telemetry must be ~free.

    Times the SAME serving feed loop twice — bare vs fully instrumented
    (MetricsRegistry + SpanTracer injected into ``SpikeServer``) — and
    records the relative overhead. The telemetry layer's hard contract is
    read-only observation of the datapath (byte-identity is pinned by
    tests/test_obs_server.py); this bench pins the PRICE and ENFORCES it:
    on the reference backend (the contract backend — interpreted Pallas
    timings are too noisy to gate) an overhead beyond ``budget`` is a
    ``SystemExit``, not a printout.
    """
    from repro.obs import MetricsRegistry, SpanTracer

    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    T = chunk_steps * rounds
    rasters = [(rng.random((T, n_in)) < activity).astype(np.int32)
               for _ in range(n_slots)]
    for backend in backends:
        engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero",
                             backend=backend)

        def make_server(telemetry: bool):
            srv = SpikeServer(
                engine, n_slots=n_slots, chunk_steps=chunk_steps,
                metrics=MetricsRegistry() if telemetry else None,
                tracer=SpanTracer() if telemetry else None)
            uids = [srv.attach() for _ in range(n_slots)]
            return srv, uids

        def chunk_at(uids, t0):
            return {u: rasters[i][t0:t0 + chunk_steps]
                    for i, u in enumerate(uids)}

        bare, bare_uids = make_server(False)
        inst, inst_uids = make_server(True)
        # time bare/instrumented back-to-back PER CHUNK (alternating which
        # goes first) and take the MEDIAN of the paired differences: two
        # sequential time_call() blocks let background-load drift
        # masquerade as telemetry overhead (a 10%+ phantom on busy CI
        # runners), and even independent per-side minima drift apart by
        # several percent on a shared machine. Pairing cancels the drift
        # (both halves of a pair see the same instant), alternation
        # cancels any first-vs-second bias, and the median discards load
        # spikes that land inside one half. Scheduling noise is still
        # several times the true telemetry cost per pair, and it only
        # INFLATES an estimate — so the gate takes the floor over three
        # independent trials: a real budget regression lifts all three,
        # a load spike lifts at most one or two.
        for t0 in range(0, T, chunk_steps):  # warmup (jit + first feed)
            bare.feed(chunk_at(bare_uids, t0))
            inst.feed(chunk_at(inst_uids, t0))
        estimates = []  # (overhead, median bare, median diff) per trial
        for trial in range(3):
            bare_s, diffs = [], []
            for it in range(7):
                for t0 in range(0, T, chunk_steps):
                    cb = chunk_at(bare_uids, t0)
                    ci = chunk_at(inst_uids, t0)
                    if (it + t0 // chunk_steps) % 2:
                        t = time.perf_counter()
                        inst.feed(ci)
                        ti = time.perf_counter() - t
                        t = time.perf_counter()
                        bare.feed(cb)
                        tb = time.perf_counter() - t
                    else:
                        t = time.perf_counter()
                        bare.feed(cb)
                        tb = time.perf_counter() - t
                        t = time.perf_counter()
                        inst.feed(ci)
                        ti = time.perf_counter() - t
                    bare_s.append(tb)
                    diffs.append(ti - tb)
            bare_s.sort()
            diffs.sort()
            med_bare = bare_s[len(bare_s) // 2]
            med_diff = diffs[len(diffs) // 2]
            estimates.append((med_diff / med_bare, med_bare, med_diff))
        overhead, med_bare, med_diff = min(estimates)
        t_bare = med_bare * rounds * 1e6  # per feed-loop, as before
        t_obs = (med_bare + med_diff) * rounds * 1e6
        emit(f"obs/overhead_{backend}", t_obs / T,
             f"instrumented {t_obs / T:.1f} vs bare {t_bare / T:.1f} "
             f"us/timestep ({100 * overhead:+.2f}% with metrics+tracer on, "
             f"{n_slots} slots x {chunk_steps}-step chunks)",
             kind="obs_overhead", backend=backend, batch=n_slots,
             activity=activity,
             bare_us_per_step=round(t_bare / T, 3),
             instrumented_us_per_step=round(t_obs / T, 3),
             overhead_frac=round(overhead, 4),
             per_timestep=True)
        if backend == "reference" and overhead > budget:
            raise SystemExit(
                f"observability overhead {overhead:.1%} exceeds the "
                f"{budget:.0%} budget on the reference backend "
                f"(instrumented {t_obs / T:.1f} vs bare {t_bare / T:.1f} "
                f"us/timestep)")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--activity", type=float, default=0.05,
                    help="fraction of sources spiking (paper: sparse)")
    ap.add_argument("--backend", choices=list(BACKENDS) + ["all"],
                    default="all",
                    help="SpikeEngine backend(s) to benchmark")
    ap.add_argument("--streaming", action="store_true",
                    help="also benchmark the SpikeServer slot-batch path "
                         "(masked chunk step vs one-shot batch scan)")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="also benchmark the AsyncSpikeFrontend request "
                         "queue: outcome counts + queue-wait/service "
                         "percentiles per backpressure policy x offered "
                         "load (under/overload on a virtual clock)")
    ap.add_argument("--sparsity", default=None, metavar="S1,S2,...",
                    help="comma list of source-activity levels for the "
                         "event-gating sweep: gated-vs-dense weight "
                         "traffic / SOP reduction per gate x backend x "
                         "serving occupancy (e.g. 0.02,0.05,0.2)")
    ap.add_argument("--fuse-steps", default=None, metavar="K1,K2,...",
                    help="comma list of K values for the K-step fusion "
                         "sweep: engine steps/s and weight-block traffic "
                         "per K x backend x sparsity x occupancy, with "
                         "the trace window-OR count cross-checked "
                         "against the kernel's gate scalars (e.g. 1,4,8)")
    ap.add_argument("--qos", action="store_true",
                    help="also benchmark the multi-tenant QoS front door: "
                         "the same adversarial burst-over-background "
                         "traffic through FIFO vs WFQ vs preemptive "
                         "admission on a virtual clock, recording "
                         "per-class p99 total latency — the isolation "
                         "claim (high-priority p99 strictly below the "
                         "FIFO baseline) is ENFORCED on the reference "
                         "backend")
    ap.add_argument("--migrate", action="store_true",
                    help="also benchmark stream-state migration overhead: "
                         "per-stream carry snapshot latency, in-memory and "
                         "file-backed detach->attach round trips, and blob "
                         "size vs the feed quantum a slot runs in that "
                         "time (the byte-identity itself is pinned by "
                         "tests/test_carry_migration.py)")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="also benchmark the telemetry layer's cost: the "
                         "same SpikeServer feed loop bare vs instrumented "
                         "(MetricsRegistry + SpanTracer), recording the "
                         "relative overhead — the observability contract "
                         "is byte-identical outputs and < 5% overhead on "
                         "the reference backend, ENFORCED: exceeding the "
                         "budget there exits nonzero")
    ap.add_argument("--devices", type=int, default=1,
                    help="also run the engine/streaming benches on a mesh "
                         "over N devices (faked host devices on CPU)")
    ap.add_argument("--mesh", default=None, metavar="KNxKB",
                    help="neuron x batch mesh split for --devices "
                         "(default: 2 x N/2 when N allows)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (BENCH_*.json)")
    return ap


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    if args.mesh and args.devices <= 1:
        raise SystemExit("--mesh requires --devices N (N > 1); without it "
                         "the sharded benches would silently not run")

    # force the faked device count BEFORE the first jax backend touch
    # (the Pallas kernel import below initializes it)
    if args.devices > 1:
        ensure_host_devices(args.devices)
    from repro.kernels import ops, ref

    backends = list(BACKENDS) if args.backend == "all" else [args.backend]
    if args.json:
        common.start_recording()

    mesh = None
    if args.devices > 1:
        try:
            kn, kb = parse_mesh_spec(args.devices, args.mesh)
        except ValueError as e:
            raise SystemExit(str(e))
        mesh = make_spike_mesh(neuron=kn, batch=kb)
        print(f"[bench] mesh axis: {kn} neuron shards x {kb} batch shards "
              f"({args.devices} devices)", flush=True)

    sparsities = None
    if args.sparsity:
        try:
            sparsities = [float(s) for s in args.sparsity.split(",") if s]
        except ValueError:
            raise SystemExit(
                f"--sparsity must be comma-separated floats, "
                f"got {args.sparsity!r}")
        bench_event_gating(backends, sparsities, batch=args.batch,
                           n_slots=max(args.batch, 8))

    if args.fuse_steps:
        try:
            fuse_list = [int(k) for k in args.fuse_steps.split(",") if k]
        except ValueError:
            raise SystemExit(
                f"--fuse-steps must be comma-separated ints, "
                f"got {args.fuse_steps!r}")
        if not fuse_list or any(k < 1 for k in fuse_list):
            raise SystemExit(
                f"--fuse-steps values must be >= 1, got {args.fuse_steps!r}")
        bench_fuse_steps(backends, fuse_list,
                         sparsities if sparsities else [args.activity],
                         batch=args.batch, n_slots=max(args.batch, 8))

    bench_engine_backends(backends, batch=args.batch,
                          activity=args.activity)
    if mesh is not None:
        bench_engine_backends(backends, batch=args.batch,
                              activity=args.activity, mesh=mesh)
    if args.streaming:
        bench_streaming(backends, n_slots=args.batch,
                        activity=args.activity)
        if mesh is not None:
            bench_streaming(backends, n_slots=args.batch,
                            activity=args.activity, mesh=mesh)
    if args.async_mode:
        bench_async_frontend(backends, activity=args.activity)
    if args.qos:
        bench_qos_frontend(backends, activity=args.activity)
    if args.migrate:
        bench_migration(backends, activity=args.activity)
    if args.obs_overhead:
        bench_obs_overhead(backends, activity=args.activity)

    rng = np.random.default_rng(0)
    B, S, P = args.batch, 784 + 1024, 1024
    src = jnp.asarray(rng.random((B, S)) < args.activity, jnp.int32)
    W = jnp.asarray(rng.integers(-2**14, 2**14, (S, P)), jnp.int32)
    v = jnp.asarray(rng.integers(-2**18, 2**18, (B, P)), jnp.int32)

    fused = lambda: ops.spike_timestep(src, W, v, decay_rate=0.25,
                                       threshold_raw=1 << 16)
    unfused = lambda: ref.spike_timestep_ref(
        src, W, v, decay_rate=0.25, threshold_raw=1 << 16,
        reset_mode="zero")

    t_fused = time_call(lambda: fused())
    t_ref = time_call(lambda: unfused())
    emit("kernel/spike_timestep_fused", t_fused,
         f"B={B} S={S} P={P} activity={args.activity}",
         kind="kernel", batch=B, activity=args.activity, devices=1)
    emit("kernel/spike_timestep_ref", t_ref, "pure-jnp oracle",
         kind="kernel", batch=B, activity=args.activity, devices=1)

    # event-gating accounting: active source blocks out of total
    blk = 128
    nblk = -(-S // blk)
    act = np.asarray(src).reshape(B, -1)
    padded = np.zeros((B, nblk * blk), np.int32)
    padded[:, :S] = act
    active_blocks = int(
        (padded.reshape(B, nblk, blk).sum(axis=(0, 2)) > 0).sum())
    emit("kernel/active_source_blocks", None,
         f"{active_blocks}/{nblk} touched -> "
         f"{100 * (1 - active_blocks / nblk):.0f}% weight traffic skipped",
         kind="accounting", active_blocks=active_blocks, total_blocks=nblk)

    # LIF + encoder micro-latencies
    vv = jnp.asarray(rng.integers(-2**20, 2**20, (B, P)), jnp.int32)
    syn = jnp.asarray(rng.integers(-2**16, 2**16, (B, P)), jnp.int32)
    t_lif = time_call(
        lambda: ops.lif_step(vv, syn, decay_rate=0.25,
                             threshold_raw=1 << 16))
    emit("kernel/lif_step", t_lif, f"B={B} N={P}",
         kind="kernel", batch=B, devices=1)
    x = jnp.asarray(rng.random((B, 784)), jnp.float32)
    t_enc = time_call(lambda: ops.poisson_encode(0, x, 25))
    emit("kernel/poisson_encode", t_enc, f"B={B} D=784 T=25",
         kind="kernel", batch=B, devices=1)

    if args.json:
        common.write_json(
            args.json,
            bench="kernel_bench",
            # devices=1 records in a --devices N run still execute on the
            # N-way faked host topology; flag it so trajectory comparisons
            # against plain single-device runs don't conflate the two.
            host_devices_forced=args.devices if args.devices > 1 else None,
            args={"batch": args.batch, "activity": args.activity,
                  "backend": args.backend, "streaming": args.streaming,
                  "async": args.async_mode, "qos": args.qos,
                  "sparsity": args.sparsity,
                  "fuse_steps": args.fuse_steps, "migrate": args.migrate,
                  "obs_overhead": args.obs_overhead,
                  "devices": args.devices, "mesh": args.mesh},
        )


if __name__ == "__main__":
    main()
