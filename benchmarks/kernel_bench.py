"""Kernel micro-benchmarks: the fused accelerator timestep vs its unfused
reference, at the paper's 1024-neuron scale (CPU wall time is NOT the
deliverable — the structural claim is the event-gated kernel touches fewer
weight blocks; timings are still printed for regression tracking).

``--backend`` additionally benchmarks the full SpikeEngine scan per
backend, so the Pallas-vs-reference speedup is measurable on real
inference timesteps (one engine, carries included) rather than only on
the isolated kernel call.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.engine import BACKENDS, DecaySpec, SpikeEngine
from repro.kernels import ops, ref
from repro.serving.snn import SpikeServer


def bench_engine_backends(backends, *, batch: int, activity: float,
                          steps: int = 4) -> None:
    """Per-backend engine-scan throughput at the 1024-neuron scale."""
    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    ext = jnp.asarray(
        rng.random((steps, batch, n_in)) < activity, jnp.int32)
    for backend in backends:
        engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero",
                             backend=backend)
        t_run = time_call(lambda e=engine: e.run(ext)["spikes"])
        per_step = t_run / steps
        emit(f"engine/timestep_{backend}", per_step,
             f"us/timestep B={batch} S={n_in + P} P={P} "
             f"activity={activity} T={steps}")


def bench_streaming(backends, *, n_slots: int, activity: float,
                    chunk_steps: int = 8, rounds: int = 3) -> None:
    """The serving axis: masked slot-batch chunk step (SpikeServer.feed)
    vs the one-shot batch scan on the same raster, plus the cost of a
    partially occupied slot batch (the serving occupancy regime)."""
    rng = np.random.default_rng(0)
    n_in, P = 784, 1024
    W = jnp.asarray(rng.integers(-2**13, 2**13, (n_in + P, P)), jnp.int32)
    T = chunk_steps * rounds
    rasters = [
        (rng.random((T, n_in)) < activity).astype(np.int32)
        for _ in range(n_slots)
    ]
    batch = jnp.asarray(np.stack(rasters, axis=1))  # (T, n_slots, n_in)
    for backend in backends:
        engine = SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                             threshold_raw=1 << 16, reset_mode="zero",
                             backend=backend)
        t_batch = time_call(lambda e=engine: e.run(batch)["spikes"])
        emit(f"streaming/batch_scan_{backend}", t_batch / T,
             f"us/timestep B={n_slots} T={T} (one-shot run)")

        for occupancy in (1.0, 0.25):
            n_live = max(1, int(round(occupancy * n_slots)))

            def serve(e=engine, n_live=n_live):
                srv = SpikeServer(e, n_slots=n_slots,
                                  chunk_steps=chunk_steps)
                uids = [srv.attach() for _ in range(n_live)]
                for t0 in range(0, T, chunk_steps):
                    srv.feed({u: rasters[i][t0:t0 + chunk_steps]
                              for i, u in enumerate(uids)})
                return srv.total_steps

            t_srv = time_call(serve)
            emit(f"streaming/feed_{backend}_occ{occupancy:g}", t_srv / T,
                 f"us/timestep {n_live}/{n_slots} slots live, "
                 f"chunk={chunk_steps} (masked step, per-chunk host hop)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--activity", type=float, default=0.05,
                    help="fraction of sources spiking (paper: sparse)")
    ap.add_argument("--backend", choices=list(BACKENDS) + ["all"],
                    default="all",
                    help="SpikeEngine backend(s) to benchmark")
    ap.add_argument("--streaming", action="store_true",
                    help="also benchmark the SpikeServer slot-batch path "
                         "(masked chunk step vs one-shot batch scan)")
    args = ap.parse_args(argv)
    backends = list(BACKENDS) if args.backend == "all" else [args.backend]

    bench_engine_backends(backends, batch=args.batch,
                          activity=args.activity)
    if args.streaming:
        bench_streaming(backends, n_slots=args.batch,
                        activity=args.activity)

    rng = np.random.default_rng(0)
    B, S, P = args.batch, 784 + 1024, 1024
    src = jnp.asarray(rng.random((B, S)) < args.activity, jnp.int32)
    W = jnp.asarray(rng.integers(-2**14, 2**14, (S, P)), jnp.int32)
    v = jnp.asarray(rng.integers(-2**18, 2**18, (B, P)), jnp.int32)

    fused = lambda: ops.spike_timestep(src, W, v, decay_rate=0.25,
                                       threshold_raw=1 << 16)
    unfused = lambda: ref.spike_timestep_ref(
        src, W, v, decay_rate=0.25, threshold_raw=1 << 16,
        reset_mode="zero")

    t_fused = time_call(lambda: fused())
    t_ref = time_call(lambda: unfused())
    emit("kernel/spike_timestep_fused", t_fused,
         f"B={B} S={S} P={P} activity={args.activity}")
    emit("kernel/spike_timestep_ref", t_ref, "pure-jnp oracle")

    # event-gating accounting: active source blocks out of total
    blk = 128
    nblk = -(-S // blk)
    act = np.asarray(src).reshape(B, -1)
    padded = np.zeros((B, nblk * blk), np.int32)
    padded[:, :S] = act
    active_blocks = int(
        (padded.reshape(B, nblk, blk).sum(axis=(0, 2)) > 0).sum())
    emit("kernel/active_source_blocks", None,
         f"{active_blocks}/{nblk} touched -> "
         f"{100 * (1 - active_blocks / nblk):.0f}% weight traffic skipped")

    # LIF + encoder micro-latencies
    vv = jnp.asarray(rng.integers(-2**20, 2**20, (B, P)), jnp.int32)
    syn = jnp.asarray(rng.integers(-2**16, 2**16, (B, P)), jnp.int32)
    t_lif = time_call(
        lambda: ops.lif_step(vv, syn, decay_rate=0.25,
                             threshold_raw=1 << 16))
    emit("kernel/lif_step", t_lif, f"B={B} N={P}")
    x = jnp.asarray(rng.random((B, 784)), jnp.float32)
    t_enc = time_call(lambda: ops.poisson_encode(0, x, 25))
    emit("kernel/poisson_encode", t_enc, f"B={B} D=784 T=25")


if __name__ == "__main__":
    main()
