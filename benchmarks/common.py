"""Shared benchmark plumbing: timing helper + CSV / JSON emission."""

from __future__ import annotations

import json
import platform
import time

import jax

from repro.bench_schema import AXIS_DEFAULTS, SCHEMA_VERSION

__all__ = ["AXIS_DEFAULTS", "SCHEMA_VERSION", "emit", "start_recording",
           "time_call", "write_json"]


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall microseconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


# Machine-readable result collection: benchmarks call start_recording()
# once, then every emit() with structured **fields is also appended to an
# in-memory record list that write_json() dumps as a BENCH_*.json — the
# repo's perf trajectory across PRs.
#
# SCHEMA_VERSION and AXIS_DEFAULTS live in repro.bench_schema (re-imported
# above) so serve_snn — which runs with PYTHONPATH=src only — can stamp
# the same schema + axes into its --json-summary meta block.

_records: list[dict] | None = None


def start_recording() -> None:
    global _records
    _records = []


def write_json(path: str, **metadata) -> None:
    if _records is None:
        raise RuntimeError("write_json() without start_recording()")
    doc = {
        "metadata": {
            "schema": SCHEMA_VERSION,
            "backend_platform": jax.default_backend(),
            "device_count": jax.device_count(),
            "jax_version": jax.__version__,
            "python_version": platform.python_version(),
            "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            **metadata,
        },
        "results": _records,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench] wrote {len(_records)} records -> {path}", flush=True)


def emit(name: str, us_per_call: float | None, derived: str,
         **fields) -> None:
    """Print one CSV line; when recording, also append a JSON record.

    ``fields`` carries the structured axes (backend, batch, occupancy,
    devices, ...); ``us_per_call`` additionally derives ``steps_per_s``
    when the metric is a per-timestep latency.
    """
    us = "" if us_per_call is None else f"{us_per_call:.1f}"
    print(f"{name},{us},{derived}", flush=True)
    if _records is not None:
        per_timestep = fields.pop("per_timestep", False)  # directive, not data
        rec = {"name": name, "info": derived, **fields}
        # schema >= 2: every record carries every cross-bench axis, so a
        # default (e.g. the default gate) is an explicit value, never a
        # missing key
        for axis, default in AXIS_DEFAULTS.items():
            rec.setdefault(axis, default)
        if us_per_call is not None:
            rec["us_per_call"] = round(us_per_call, 3)
            if per_timestep:
                rec["steps_per_s"] = round(1e6 / us_per_call, 3)
        _records.append(rec)
