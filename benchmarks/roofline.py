"""§Roofline report — reads results/dryrun.json, prints the full table.

One row per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs utilization, memory footprint. This is
the artifact EXPERIMENTS.md §Roofline embeds; the §Perf hillclimb reads
the same numbers before/after each change.
"""

from __future__ import annotations

import argparse
import json
import os


def fmt_row(r: dict) -> str:
    if r["status"] == "skip":
        return (f"{r['arch']},{r['shape']},{r['mesh']},SKIP,,,,,,,"
                f"\"{r['reason'][:60]}\"")
    if r["status"] == "fail":
        return f"{r['arch']},{r['shape']},{r['mesh']},FAIL,,,,,,,"
    t = r["roofline"]
    mem_gb = r["memory"]["total_bytes"] / 2**30
    return (f"{r['arch']},{r['shape']},{r['mesh']},ok,"
            f"{t['compute_s']:.3e},{t['memory_s']:.3e},"
            f"{t['collective_s']:.3e},{t['dominant']},"
            f"{t['useful_flops_ratio']:.3f},{t['roofline_fraction']:.3f},"
            f"{mem_gb:.2f}")


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun.json")
    ap.add_argument("--mesh", default="all",
                    help="single_pod_16x16 | multi_pod_2x16x16 | all")
    args = ap.parse_args(argv)

    if not os.path.exists(args.results):
        print(f"# no dry-run results at {args.results}; run "
              f"`python -m repro.launch.dryrun` first")
        return []
    recs = json.load(open(args.results))
    if args.mesh != "all":
        recs = [r for r in recs if r["mesh"] == args.mesh]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("arch,shape,mesh,status,compute_s,memory_s,collective_s,"
          "dominant,useful_flops_ratio,roofline_fraction,mem_gib_per_dev")
    for r in recs:
        print(fmt_row(r))

    ok = [r for r in recs if r["status"] == "ok"]
    from collections import Counter
    doms = Counter(r["roofline"]["dominant"] for r in ok)
    print(f"# {len(ok)} ok cells; dominant terms: {dict(doms)}")
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    print("# worst roofline fractions: "
          + "; ".join(f"{r['arch']}/{r['shape']}/{r['mesh'].split('_')[0]}"
                      f"={r['roofline']['roofline_fraction']:.3f}"
                      for r in worst))

    # §Perf variants: paper-faithful baseline vs optimized, side by side
    vpath = os.path.join(os.path.dirname(args.results),
                         "dryrun_variants.json")
    if os.path.exists(vpath):
        base = {(r["arch"], r["shape"], r["mesh"]): r for r in recs
                if r["status"] == "ok"}
        print("\n# §Perf variants (baseline -> optimized)")
        print("arch,shape,mesh,variant,bound_before_s,bound_after_s,"
              "delta_pct,gib_before,gib_after")
        for r in json.load(open(vpath)):
            key = (r["arch"], r["shape"], r["mesh"])
            if r["status"] != "ok" or key not in base:
                continue
            b = base[key]
            b0 = b["roofline"]["bound_s"]
            b1 = r["roofline"]["bound_s"]
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['variant']},"
                  f"{b0:.3e},{b1:.3e},{100 * (b1 - b0) / b0:+.1f}%,"
                  f"{b['memory']['total_bytes'] / 2**30:.1f},"
                  f"{r['memory']['total_bytes'] / 2**30:.1f}")
    return recs


if __name__ == "__main__":
    main()
