"""Paper Table IV — HW-vs-SW accuracy across hidden sizes x timestep grids.

The paper's full grid is 5 hidden sizes x 4 train-T x 4 infer-T = 80
experiments. The default here runs the width sweep with (train_T, infer_T)
= (25, 25) — one experiment per width, CPU-sized — and ``--full`` runs the
whole 80 (examples/train_mnist_snn.py --grid drives that path too).

Reports software acc, hardware acc, deviation (the paper's headline:
-2.62 % average, shrinking with width).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.lif import LIFParams
from repro.data import mnist
from repro.snn.model import SNNModelConfig
from repro.snn.train import TrainConfig, evaluate_dual, train

HIDDEN_SIZES = (16, 32, 64, 128, 256)
T_GRID = (25, 50, 75, 100)


def run_cell(hidden: int, train_T: int, infer_T: int, *,
             train_steps: int, eval_n: int, seed: int = 0) -> dict:
    cfg = TrainConfig(
        model=SNNModelConfig(layer_sizes=(784, hidden, 10),
                             params=LIFParams(decay_rate=0.1)),
        num_steps_time=train_T, lr=3e-3, batch_size=96,
        train_steps=train_steps, seed=seed)
    data = mnist.batches("train", cfg.batch_size, cfg.train_steps, seed=seed)
    params, _, _ = train(cfg, data, log_every=0)
    x, y = mnist.load_or_generate("test", eval_n, seed=seed + 1)
    res = evaluate_dual(params, cfg.model, x, y, num_steps_time=infer_T)
    return {
        "hidden": hidden, "train_T": train_T, "infer_T": infer_T,
        "software_acc": res["software_acc"],
        "hardware_acc": res["hardware_acc"],
        "deviation_pct": res["deviation_pct"],
        "agreement": res["agreement"],
    }


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="run the paper's full 80-experiment grid")
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--eval-n", type=int, default=512)
    args = ap.parse_args(argv)

    grid = ([(h, tt, it) for h in HIDDEN_SIZES for tt in T_GRID
             for it in T_GRID] if args.full
            else [(h, 25, 25) for h in HIDDEN_SIZES])

    rows, by_hidden = [], {}
    for h, tt, it in grid:
        r = run_cell(h, tt, it, train_steps=args.train_steps,
                     eval_n=args.eval_n)
        rows.append(r)
        by_hidden.setdefault(h, []).append(r)
        emit(f"table_iv/h{h}_T{tt}x{it}", None,
             f"sw={r['software_acc']:.4f} hw={r['hardware_acc']:.4f} "
             f"dev={r['deviation_pct']:+.2f}pp agree={r['agreement']:.3f}")

    print()
    print("hidden,software_acc,hardware_acc,diff_pp,n_exp")
    devs = []
    for h in sorted(by_hidden):
        rs = by_hidden[h]
        sw = np.mean([r["software_acc"] for r in rs]) * 100
        hw = np.mean([r["hardware_acc"] for r in rs]) * 100
        print(f"{h},{sw:.2f},{hw:.2f},{hw - sw:+.2f},{len(rs)}")
        devs.append(hw - sw)
    print(f"overall_avg_deviation_pp,{np.mean(devs):+.2f}")
    print("paper_reference: -2.62pp avg; -5.72 @16 -> -0.63 @256")
    return rows


if __name__ == "__main__":
    main()
