"""Paper Table V — component-level power breakdown on an MNIST workload.

Runs the bit-exact Cerebra-H model on rate-coded procedural-MNIST inference,
collects true event counts (SOPs, SRAM row fetches, NoC packets, cycles),
and evaluates the calibrated energy model. The headline reproduction: the
weight-memory subsystem dominates total power (~96 %) while the compute
path runs at 1.05 pJ/SOP.

``--measured-sop`` sources the event counts from the spike-trace recorder
(``events.trace.measured_counts``): SOPs and row fetches are COUNTED from
the real rasters the run emitted, independently of the cost model's
analytic pass, and both accountings are printed side by side — agreement
is the cross-check (arXiv:2309.03388: SOP energy must be measured, not
estimated), and the measured path is the one streaming rasters (which
never see a frontend cost model) go through.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import cerebra_h, coding, energy
from repro.core.lif import LIFParams
from repro.data import mnist
from repro.events import trace
from repro.snn.model import SNNModelConfig, init_params, to_snnetwork


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--measured-sop", action="store_true",
                    help="use event counts measured from the real rasters "
                         "(events.trace) for the energy rows, and print "
                         "them next to the analytic cost-model counts")
    args = ap.parse_args(argv)

    cfg = SNNModelConfig(layer_sizes=(784, args.hidden, 10),
                         params=LIFParams(decay_rate=0.25))
    params = init_params(jax.random.key(0), cfg)
    net = to_snnetwork(params, cfg)
    prog = cerebra_h.compile_network(net)

    x, _ = mnist.load_or_generate("test", args.batch, seed=0)
    spikes = coding.poisson_encode(jax.random.key(1), x, args.steps,
                                   dtype=np.int32)
    out = cerebra_h.run(prog, spikes)
    counts = energy.counts_from_run(out)
    if args.measured_sop:
        analytic = counts
        counts = trace.measured_counts(prog, spikes, out["spikes"])
        for field in ("sops", "row_fetches"):
            m, a = getattr(counts, field), getattr(analytic, field)
            delta = 100 * (m - a) / max(a, 1.0)
            emit(f"table_v/{field}_measured_vs_analytic", None,
                 f"measured {m:.3e} vs analytic {a:.3e} "
                 f"({delta:+.2f}% delta)")
        rep = trace.trace_run(cerebra_h.make_engine(prog), spikes,
                              out["spikes"])
        emit("table_v/gated_weight_traffic", None,
             f"per-example gate {100 * rep.traffic_ratio('per-example'):.1f}%"
             f" of dense blocks (batch-tile "
             f"{100 * rep.traffic_ratio('batch-tile'):.1f}%), source "
             f"sparsity {100 * rep.source_sparsity:.2f}%")

    model = energy.EnergyModel.calibrated()
    mw = model.breakdown_mw(counts)
    uj = model.energy_uj(counts)

    emit("table_v/sops", None, f"{counts.sops:.3e}")
    emit("table_v/row_fetches", None, f"{counts.row_fetches:.3e}")
    emit("table_v/cycles", None, f"{counts.cycles:.3e}")
    print()
    print("subsystem,power_mw,pct,paper_mw")
    paper = energy.TABLE_V
    for k, pk in [("weight_memory_mw", "weight_memory_mw"),
                  ("neuron_clusters_mw", "neuron_clusters_mw"),
                  ("spike_paths_mw", "spike_paths_mw"),
                  ("data_control_paths_mw", "data_control_paths_mw")]:
        print(f"{k},{mw[k]:.2f},{100 * mw[k] / mw['total_mw']:.2f},"
              f"{paper[pk]:.2f}")
    print(f"total,{mw['total_mw']:.2f},100.00,{paper['total_mw']:.2f}")
    print(f"weight_memory_dominance_pct,{mw['weight_memory_pct']:.2f},"
          f",95.97")
    print(f"compute_pj_per_sop,{mw['compute_pj_per_sop']:.2f},,1.05")
    print(f"system_pj_per_sop,{uj['pj_per_sop_system']:.1f},,")
    return {"mw": mw, "uj": uj, "counts": counts}


if __name__ == "__main__":
    main()
