"""Paper Table III — Cerebra-H vs representative neuromorphic systems.

Literature rows are constants from the paper; the SNAP-V row is *derived
from our models* (energy model + timing model), so any change to the
reproduction shows up here. As the paper notes, the comparison is not
normalized for technology node or memory style — context, not ranking.
"""

from __future__ import annotations

from repro.core import energy, timing

LITERATURE = [
    # name, tech, area_mm2, neurons, freq_mhz, power_w, pj_per_sop
    ("ODIN", "28nm FD-SOI", 0.086, 256, "75-100", None, 12.7),
    ("OpenSpike", "130nm", 33.3, 1024, 40, 0.225, None),
    ("TrueNorth", "28nm CMOS", 430, 1_000_000, 0.001, 0.065, 26.0),
    ("Loihi1", "14nm FinFET", 60, 131_000, None, None, 23.6),
    ("Loihi2", "Intel4", 31, 1_000_000, 1000, 1.55, 10.8),
    ("DYNAPs", "180nm CMOS", 43.79, 1024, None, None, 26.0),
    ("SpiNNaker", "130nm", 102, 250_000, 200, 1.0, 1500.0),
    ("4096-Neuron", "10nm FinFET", 1.72, 4096, "105-506", None, 3.8),
]


def main(argv=None) -> list[tuple]:
    model = energy.EnergyModel.calibrated()
    ref = model.reference_rates
    counts = energy.WorkloadCounts(
        sops=ref["sops_per_s"], row_fetches=ref["rows_per_s"],
        spike_packets=ref["packets_per_s"],
        cycles=model.freq_mhz * 1e6)
    mw = model.breakdown_mw(counts)

    rows = [("SNAP-V(this-work)", "45nm CMOS", energy.AREA_MM2, 1024,
             timing.FREQ_H_MHZ, mw["total_mw"] / 1e3, model.e_sop_pj)]
    rows += LITERATURE

    print("design,tech,area_mm2,neurons,freq_mhz,power_w,pj_per_sop")
    for name, tech, area, n, f, p, e in rows:
        print(f"{name},{tech},{area},{n},{f if f is not None else ''},"
              f"{'' if p is None else p},{'' if e is None else e}")
    # derived sanity notes
    ours = rows[0]
    competitive = [r for r in LITERATURE if r[6] is not None
                   and r[6] < ours[6]]
    print(f"# SNAP-V pJ/SOP={ours[6]} — lower than "
          f"{sum(1 for r in LITERATURE if (r[6] or 0) > ours[6])}"
          f"/{len(LITERATURE)} published rows (paper claim: most "
          f"competitive at its 1024-neuron scale)")
    assert not competitive, "calibration drifted: 1.05 pJ/SOP must lead"
    return rows


if __name__ == "__main__":
    main()
