"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--full-grid]

Emits ``name,us_per_call,derived`` CSV lines per benchmark plus the
formatted tables. Sections:

  table_iv   — HW-vs-SW accuracy grid (paper Table IV)
  table_v    — power breakdown on an MNIST workload (paper Table V)
  table_iii  — systems comparison (paper Table III)
  speedup    — Cerebra-S vs Cerebra-H cycles + wall time (paper §VII-B)
  kernels    — Pallas kernel micro-benchmarks + event-gating accounting
  roofline   — 40-cell dry-run roofline table (EXPERIMENTS.md §Roofline)
"""

from __future__ import annotations

import argparse
import sys
import time


def _section(title: str) -> None:
    print(f"\n===== {title} =====", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced training budgets (CI-sized)")
    ap.add_argument("--full-grid", action="store_true",
                    help="run the paper's full 80-experiment Table IV grid")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections")
    ap.add_argument("--backend", default=None,
                    help="SpikeEngine backend for the kernels/speedup "
                         "sections (reference | pallas | pallas-mxu)")
    args = ap.parse_args()

    only = set(args.only.split(",")) if args.only else None
    t0 = time.time()

    def want(name: str) -> bool:
        return only is None or name in only

    backend_args = ["--backend", args.backend] if args.backend else []

    if want("kernels"):
        _section("kernels")
        from benchmarks import kernel_bench
        kernel_bench.main(backend_args)

    if want("table_v"):
        _section("table_v (power breakdown)")
        from benchmarks import table_v_power
        table_v_power.main(["--steps", "50"] if args.fast else [])

    if want("table_iii"):
        _section("table_iii (systems comparison)")
        from benchmarks import table_iii_comparison
        table_iii_comparison.main([])

    if want("speedup"):
        _section("speedup (Cerebra-S vs Cerebra-H)")
        from benchmarks import speedup_s_vs_h
        speedup_s_vs_h.main(
            (["--steps", "25"] if args.fast else []) + backend_args)

    if want("table_iv"):
        _section("table_iv (accuracy grid)")
        from benchmarks import table_iv_accuracy
        grid_args = []
        if args.full_grid:
            grid_args.append("--full")
        if args.fast:
            grid_args += ["--train-steps", "60", "--eval-n", "256"]
        table_iv_accuracy.main(grid_args)

    if want("roofline"):
        _section("roofline (from dry-run artifacts)")
        from benchmarks import roofline
        roofline.main([])

    print(f"\n[benchmarks] done in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
