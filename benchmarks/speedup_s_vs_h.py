"""Paper §VII-B — Cerebra-S vs Cerebra-H speedup on the same workload.

The paper reports f_max 10.17 MHz (S) -> 96.24 MHz (H), a 9.46x clock
improvement, PLUS the architectural cycle reduction from parallel cluster
groups + hierarchical NoC. We run the same logical network through both
cycle-accurate cost models and report cycles/timestep and wall time at the
synthesized clocks — the total speedup = clock x cycle gain.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import cerebra_h, cerebra_s, coding, timing
from repro.core.engine import BACKENDS
from repro.core.lif import LIFParams
from repro.data import mnist
from repro.snn.model import SNNModelConfig, init_params, to_snnetwork


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--backend", choices=BACKENDS, default="reference",
                    help="SpikeEngine backend for both generations")
    args = ap.parse_args(argv)

    cfg = SNNModelConfig(layer_sizes=(784, args.hidden, 10),
                         params=LIFParams(decay_rate=0.25))
    params = init_params(jax.random.key(0), cfg)
    net = to_snnetwork(params, cfg)

    x, _ = mnist.load_or_generate("test", args.batch, seed=0)
    spikes = coding.poisson_encode(jax.random.key(1), x, args.steps,
                                   dtype=np.int32)

    outS = cerebra_s.run(cerebra_s.compile_network(net), spikes,
                         backend=args.backend)
    outH = cerebra_h.run(cerebra_h.compile_network(net), spikes,
                         backend=args.backend)
    # per-image mean cycles per timestep
    cyc_s = np.asarray(outS["cycles"], np.float64).mean()
    cyc_h = np.asarray(outH["cycles"], np.float64).mean()
    rep = timing.speedup_report(np.asarray(outS["cycles"]).mean(axis=1),
                                np.asarray(outH["cycles"]).mean(axis=1))

    emit("speedup/cycles_per_step_S", None, f"{cyc_s:.1f}")
    emit("speedup/cycles_per_step_H", None, f"{cyc_h:.1f}")
    emit("speedup/cycle_speedup", None, f"{rep.cycle_speedup:.2f}x")
    emit("speedup/clock_speedup", None,
         f"{rep.clock_speedup:.2f}x (paper: 9.46x)")
    emit("speedup/total_speedup", None, f"{rep.total_speedup:.2f}x")
    emit("speedup/time_per_inference_S_us", None,
         f"{rep.time_s_us / 1.0:.1f}")
    emit("speedup/time_per_inference_H_us", None,
         f"{rep.time_h_us / 1.0:.1f}")
    return {"report": rep}


if __name__ == "__main__":
    main()
